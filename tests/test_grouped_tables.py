"""Group-indexed tables (core/SEMANTICS.md §Group-indexed tables): the
grouped path must be a pure performance change — schedules bit-exact with
the dense path for every scheduler, energy to f32 rounding — together with
the two structure knobs that share its static trace key: the burst-merging
scheduler repeat (``merge_bursts``) and the queue-aware ``"pack"`` node
order, both mirrored in the sequential oracle."""
import dataclasses

import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.policy import from_label, scheduler_labels
from repro.core.ref.pydes import run_pydes
from repro.core.tables import _uniform_rows, group_tables
from repro.core.types import EngineConfig
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import (
    PlatformSpec,
    curie_platform,
    dvfs_platform_example,
    mixed_platform_example,
)
from repro.workloads.workload import workload_from_arrays

SIX = [l for l in scheduler_labels() if "AlwaysOn" not in l]
DVFS_LABELS = [
    l for l in scheduler_labels(include_dvfs=True)
    if l not in scheduler_labels()
]

# grouped vs dense: every schedule/accounting field must be bit-exact;
# energy is compared separately (the [G, 5] occ · power contraction sums
# in a different order than the dense per-node reduce — f32 rounding)
SCHEDULE_FIELDS = (
    "t", "job_start", "job_finish", "job_status", "job_eff",
    "job_terminated", "node_state", "node_until", "n_batches", "n_allocs",
    "n_starts", "n_completions", "n_switch_on", "n_switch_off", "truncated",
)


def _assert_grouped_matches_dense(grp, dense):
    for fld in SCHEDULE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(grp, fld)), np.asarray(getattr(dense, fld)),
            err_msg=f"grouped/dense diverged in SimState.{fld}",
        )
    np.testing.assert_allclose(
        np.asarray(grp.energy), np.asarray(dense.energy), rtol=1e-6,
        err_msg="grouped energy drifted past f32 rounding",
    )


# ------------------------------------------------- grouped == dense == oracle

@pytest.mark.parametrize("label", SIX)
def test_grouped_bit_exact_all_labels(label):
    """Grouped == dense == sequential oracle on a 3-group mixed platform."""
    base, pol = from_label(label)
    plat = mixed_platform_example(12)
    wl = generate_workload(
        GeneratorConfig(n_jobs=40, nb_res=12, seed=5, overrun_prob=0.2)
    )
    cfg = EngineConfig(
        base=base, policy=pol, timeout=120, terminate_overrun=True,
        node_order="cheap",
    )
    dense = engine.simulate(plat, wl, cfg)
    grp = engine.simulate(
        plat, wl, dataclasses.replace(cfg, grouped_tables=True)
    )
    _assert_grouped_matches_dense(grp, dense)

    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(grp), des.schedule_table())
    m_grp = metrics_from_state(grp, plat)
    assert m_grp.total_energy_j == pytest.approx(
        m_ref.total_energy_j, rel=1e-5
    )


@pytest.mark.parametrize("label", DVFS_LABELS)
def test_grouped_bit_exact_dvfs(label):
    """DVFS labels: the grouped ACTIVE-row override (per-mode watts) keeps
    the mode-resolved draw identical to the dense gather."""
    base, pol = from_label(label)
    plat = dvfs_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=12, seed=3))
    cfg = EngineConfig(
        base=base, policy=pol, timeout=90, node_order="cheap"
    )
    dense = engine.simulate(plat, wl, cfg)
    grp = engine.simulate(
        plat, wl, dataclasses.replace(cfg, grouped_tables=True)
    )
    _assert_grouped_matches_dense(grp, dense)

    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(grp), des.schedule_table())
    m_grp = metrics_from_state(grp, plat)
    assert m_grp.total_energy_j == pytest.approx(
        m_ref.total_energy_j, rel=1e-5
    )


def test_grouped_bit_exact_traced_sweep():
    """The traced superset program (sweep) honors grouped_tables too."""
    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=12, seed=7))
    cfg = EngineConfig(timeout=90, node_order="cheap")
    dense = engine.sweep(plat, wl, SIX, cfg)
    grp = engine.sweep(
        plat, wl, SIX, dataclasses.replace(cfg, grouped_tables=True)
    )
    _assert_grouped_matches_dense(grp.states, dense.states)


def test_grouped_bit_exact_curie_platform():
    """The benchmark platform itself (scaled down): 3 Curie groups with
    distinct watts/delays/speeds."""
    plat = curie_platform(30)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=30, seed=11))
    cfg = EngineConfig(
        base=from_label("EASY PSUS")[0], policy=from_label("EASY PSUS")[1],
        timeout=120, node_order="cheap",
    )
    dense = engine.simulate(plat, wl, cfg)
    grp = engine.simulate(
        plat, wl, dataclasses.replace(cfg, grouped_tables=True)
    )
    _assert_grouped_matches_dense(grp, dense)


def test_grouped_kernel_route_matches_xla():
    """cfg.fused_kernel=True routes the grouped event pass through the
    Pallas occ kernel (interpret on CPU) — same schedule and energy as the
    grouped XLA spelling."""
    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=12, seed=2))
    cfg = EngineConfig(
        timeout=100, node_order="cheap", grouped_tables=True,
    )
    xla = engine.simulate(
        plat, wl, dataclasses.replace(cfg, fused_kernel=False)
    )
    kern = engine.simulate(
        plat, wl, dataclasses.replace(cfg, fused_kernel=True)
    )
    _assert_grouped_matches_dense(kern, xla)


# ----------------------------------------------------------- table lowering

def test_grouped_occ_invariant():
    """The running [G, 5] occupancy ledger partitions the nodes: each
    group's row sums to its node count, at init and at the final state."""
    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=12, seed=5))
    cfg = EngineConfig(timeout=100, node_order="cheap", grouped_tables=True)
    tabs = group_tables(plat, cfg)
    s0 = engine.init_state(plat, wl, cfg)
    s = engine.simulate(plat, wl, cfg)
    for state in (s0, s):
        np.testing.assert_array_equal(
            np.asarray(state.occ).sum(axis=1), np.asarray(tabs.count)
        )


def test_group_tables_lowering():
    """Homogeneous platform lowers to one group; the mixed platform keeps
    its distinct per-group rows; node_order='id' leaves perm = identity."""
    cfg = EngineConfig(node_order="id")
    plat_h = PlatformSpec(nb_nodes=8)
    t_h = group_tables(plat_h, cfg)
    assert t_h.count.shape == (1,) and int(t_h.count[0]) == 8
    np.testing.assert_array_equal(np.asarray(t_h.perm), np.arange(8))

    plat_m = mixed_platform_example(12)
    t_m = group_tables(plat_m, cfg)
    G = plat_m.n_groups()
    assert t_m.power.shape == (G, 5)
    assert int(np.asarray(t_m.count).sum()) == 12
    # groups are genuinely heterogeneous — the [G] tables carry it
    assert len({float(x) for x in np.asarray(t_m.power)[:, 3]}) > 1

    # "cheap" orders whole groups by active watts: perm must list every
    # node of a cheaper group before any node of a dearer one
    t_c = group_tables(plat_m, EngineConfig(node_order="cheap"))
    gid = np.repeat(np.arange(G), np.asarray(t_c.count))
    key = np.asarray(t_c.order_key)[gid[np.asarray(t_c.perm)]]
    assert np.all(np.diff(key) >= 0)


def test_uniform_rows_rejects_intra_group_variation():
    """Per-node tables that vary within a group cannot be lowered — the
    builder must refuse loudly, steering to the dense path."""
    gid = np.asarray([0, 0, 1], np.int32)
    bad = np.asarray([[1.0], [2.0], [3.0]], np.float32)
    with pytest.raises(ValueError, match="varies within a node group"):
        _uniform_rows("watts", bad, gid, 2)
    ok = np.asarray([[1.0], [1.0], [3.0]], np.float32)
    np.testing.assert_array_equal(
        _uniform_rows("watts", ok, gid, 2), [[1.0], [3.0]]
    )


def test_grouped_static_trace_key():
    """grouped_tables and merge_bursts are trace structure: flipping either
    must change the jit-cache key (else a program compiled for one path
    would silently serve the other)."""
    plat = PlatformSpec(nb_nodes=8)
    cfg = EngineConfig()
    key = engine._static_trace_key(plat, cfg, 10, 64)
    key_g = engine._static_trace_key(
        plat, dataclasses.replace(cfg, grouped_tables=True), 10, 64
    )
    key_m = engine._static_trace_key(
        plat, dataclasses.replace(cfg, merge_bursts=True), 10, 64
    )
    assert len({key, key_g, key_m}) == 3


def test_sweep_rejects_tables_scenario_override():
    """Grouped tables are derived from the platform — a raw 'tables'
    scenario override would desync them from group_id/power."""
    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=10, nb_res=12, seed=1))
    cfg = EngineConfig(timeout=60, grouped_tables=True)
    tabs = group_tables(plat, cfg)
    with pytest.raises(TypeError, match="cannot override 'tables'"):
        engine.sweep(plat, wl, [{"tables": tabs}], cfg)


# ------------------------------------------------------------- merge bursts

def _burst_workload(n_jobs=100, runtime=30):
    res = np.ones(n_jobs, np.int64)
    subtime = np.zeros(n_jobs, np.int64)
    run = np.full(n_jobs, runtime, np.int64)
    return workload_from_arrays(res, subtime, run, nb_res=n_jobs)


def test_merge_bursts_drains_burst_in_one_batch():
    """A same-timestamp burst wider than the scan window W starts entirely
    at t=0 under merge_bursts (the pass repeats until quiescent); without
    the merge the tail past W waits for the next unrelated event."""
    plat = PlatformSpec(nb_nodes=100)
    wl = _burst_workload(100)
    cfg = EngineConfig(timeout=300, window=32)
    merged = engine.simulate(
        plat, wl, dataclasses.replace(cfg, merge_bursts=True)
    )
    plain = engine.simulate(plat, wl, cfg)
    np.testing.assert_array_equal(np.asarray(merged.job_start), 0)
    assert int(np.asarray(plain.job_start).max()) > 0
    assert int(merged.n_batches) < int(plain.n_batches)


def test_merge_bursts_fused_bit_exact():
    """With the flag on, the fused and legacy loops run the same repeated
    pass — bit-exact, energy included (both dense)."""
    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=60, nb_res=12, seed=4))
    cfg = EngineConfig(timeout=100, node_order="cheap", merge_bursts=True)
    fused = engine.simulate(plat, wl, cfg)
    legacy = engine.simulate(
        plat, wl, dataclasses.replace(cfg, fused_events=False)
    )
    for fld in SCHEDULE_FIELDS + ("energy", "energy_c"):
        np.testing.assert_array_equal(
            np.asarray(getattr(fused, fld)), np.asarray(getattr(legacy, fld)),
            err_msg=f"fused/legacy diverged in SimState.{fld} (merge_bursts)",
        )


@pytest.mark.parametrize("label", ["EASY PSUS", "FCFS PSAS+IPM"])
def test_merge_bursts_oracle_parity(label):
    """The oracle repeats only the scheduler pass under the same condition
    (allocations made AND eligible jobs remain) — schedules must agree."""
    base, pol = from_label(label)
    plat = PlatformSpec(nb_nodes=100)
    wl = _burst_workload(100)
    cfg = EngineConfig(
        base=base, policy=pol, timeout=300, window=32, merge_bursts=True
    )
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


def test_merge_bursts_grouped_combination():
    """Both knobs on at once (the bench_curie configuration)."""
    plat = curie_platform(30)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=30, seed=6))
    cfg = EngineConfig(timeout=120, node_order="cheap", merge_bursts=True)
    dense = engine.simulate(plat, wl, cfg)
    grp = engine.simulate(
        plat, wl, dataclasses.replace(cfg, grouped_tables=True)
    )
    _assert_grouped_matches_dense(grp, dense)


# ---------------------------------------------------------------- pack order

@pytest.mark.parametrize("label", ["EASY PSUS", "FCFS PSAS", "EASY PSAS+IPM"])
def test_pack_order_oracle_parity(label):
    """node_order='pack' (fill draining groups first) is mirrored in the
    sequential oracle: same frozen per-pass key, same schedules."""
    base, pol = from_label(label)
    plat = mixed_platform_example(12)
    wl = generate_workload(
        GeneratorConfig(n_jobs=60, nb_res=12, seed=8, overrun_prob=0.2)
    )
    cfg = EngineConfig(
        base=base, policy=pol, timeout=120, terminate_overrun=True,
        node_order="pack",
    )
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


def test_pack_order_grouped_matches_dense():
    """pack is a traced per-pass key, so it works on both paths — and they
    must still agree bit-exactly."""
    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=12, seed=9))
    cfg = EngineConfig(timeout=100, node_order="pack")
    dense = engine.simulate(plat, wl, cfg)
    grp = engine.simulate(
        plat, wl, dataclasses.replace(cfg, grouped_tables=True)
    )
    _assert_grouped_matches_dense(grp, dense)


def test_pack_prefers_idle_over_waking_sleepers():
    """The pack band: as long as idle-unreserved capacity exists anywhere,
    packing must not wake sleeping nodes (the band term dominates the
    within-band count key)."""
    plat = PlatformSpec(nb_nodes=8)
    # two 1-node jobs, well apart: after the first completes and its node
    # suspends (timeout 5), the second must reuse the still-idle pool, not
    # power the sleeper back on
    res = np.asarray([4, 1], np.int64)
    subtime = np.asarray([0, 200], np.int64)
    run = np.asarray([10, 10], np.int64)
    wl = workload_from_arrays(res, subtime, run, nb_res=8)
    cfg = EngineConfig(timeout=5, node_order="pack")
    s = engine.simulate(plat, wl, cfg)
    assert int(s.n_switch_on) == 0
