"""Paper Fig. 1 regression: same-time event batching.

Two jobs complete simultaneously; two queued jobs wait (Job 3 wants 2 nodes,
Job 4 wants 1). Atomic batching starts Job 3 on both nodes; the Batsim bug
(completions delivered one at a time) backfills Job 4 first and delays
Job 3 — divergent schedules from logically equivalent runs. The JAX engine
cannot express the bug (a vectorized batch is atomic by construction); the
oracle reproduces it under ``split_simultaneous_events=True``."""
import numpy as np

from repro.core import engine
from repro.core.metrics import schedule_table
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.platform import PlatformSpec
from repro.workloads.workload import workload_from_arrays


def fig1_workload():
    # jobs 0,1 run immediately on the 2 nodes and finish together at t=100;
    # job 2 (paper's Job 3) needs both nodes; job 3 (paper's Job 4) needs 1
    # and fits inside the EASY shadow window (job 1's predicted completion is
    # t=120, so a reqtime-18 job backfills when only ONE completion has been
    # delivered — the Batsim split-message bug).
    return workload_from_arrays(
        res=[1, 1, 2, 1],
        subtime=[0, 0, 10, 10],
        runtime=[100, 100, 50, 15],
        reqtime=[120, 120, 60, 18],
        nb_res=2,
    )


CFG = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS)


def test_batched_oracle_starts_job3_first():
    _, des = run_pydes(PlatformSpec(nb_nodes=2), fig1_workload(), CFG)
    tab = des.schedule_table()
    # atomic: both completions seen -> job 2 (2 nodes) starts at t=100
    assert tab[2, 0] == 100.0
    # job 3 runs after job 2 releases the nodes
    assert tab[3, 0] == 150.0


def test_split_mode_reproduces_batsim_bug():
    _, des_ok = run_pydes(PlatformSpec(nb_nodes=2), fig1_workload(), CFG)
    _, des_bug = run_pydes(
        PlatformSpec(nb_nodes=2),
        fig1_workload(),
        CFG,
        split_simultaneous_events=True,
    )
    tab_ok = des_ok.schedule_table()
    tab_bug = des_bug.schedule_table()
    # bug: first completion alone -> head job 2 blocked -> job 4 backfilled
    assert tab_bug[3, 0] == 100.0  # job 4 jumped the queue
    assert tab_bug[2, 0] > tab_ok[2, 0]  # job 3 delayed
    assert not np.array_equal(tab_ok, tab_bug)


def test_jax_engine_matches_batched_oracle():
    s = engine.simulate(PlatformSpec(nb_nodes=2), fig1_workload(), CFG)
    _, des = run_pydes(PlatformSpec(nb_nodes=2), fig1_workload(), CFG)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())


def test_simultaneous_completion_count():
    """The atomic engine processes both completions in ONE batch."""
    s = engine.simulate(PlatformSpec(nb_nodes=2), fig1_workload(), CFG)
    # 4 jobs complete; completions happen in 3 batches (two together)
    assert int(s.n_completions) == 4
