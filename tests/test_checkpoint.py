"""Checkpointer: roundtrip (incl. bf16), atomic publish under mid-write
crash, async writes, restart-from-latest, retention GC."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import Checkpointer, restore_or_init


def tree():
    return {
        "w": jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4),
        "emb": jnp.ones((5, 2), jnp.bfloat16) * 1.5,
        "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)},
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        )


def test_roundtrip_bf16(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(7, t)
    step, out = ck.restore(jax.eval_shape(lambda: t))
    assert step == 7
    assert_tree_equal(t, out)


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    for s in (1, 2, 3):
        ck.save_async(s, jax.tree_util.tree_map(lambda x: x * s, t))
    ck.wait()
    assert ck.latest_step() == 3
    _, out = ck.restore(jax.eval_shape(lambda: t))
    assert_tree_equal(jax.tree_util.tree_map(lambda x: x * 3, t), out)


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, tree())
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_torn_write_is_invisible(tmp_path):
    """A tmp dir left by a killed writer is never seen by restore."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    # simulate a crash mid-write of step 2: tmp dir exists, no publish
    torn = os.path.join(str(tmp_path), "step_00000002.tmp-999")
    os.makedirs(torn)
    with open(os.path.join(torn, "garbage.npy"), "w") as f:
        f.write("not-an-array")
    assert ck.latest_step() == 1
    _, out = ck.restore(jax.eval_shape(lambda: tree()))
    assert_tree_equal(tree(), out)


def test_stale_latest_pointer_rejected(tmp_path):
    """LATEST pointing at a deleted dir -> treated as no checkpoint."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    shutil.rmtree(os.path.join(str(tmp_path), "step_00000001"))
    assert ck.latest_step() is None


def test_restore_or_init(tmp_path):
    ck = Checkpointer(str(tmp_path))
    step, t0 = restore_or_init(ck, tree)
    assert step == 0
    ck.save(4, t0)
    step, t1 = restore_or_init(ck, tree)
    assert step == 4
    assert_tree_equal(t0, t1)


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    bad = dict(tree())
    bad["w"] = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(ValueError, match="shape"):
        ck.restore(jax.eval_shape(lambda: bad))


# ------------------------------------------------------ RL policy versioning


def test_policy_checkpoint_roundtrip(tmp_path):
    from repro.core.rl.networks import policy_init
    from repro.training.checkpoint import load_policy, save_policy

    params = policy_init(jax.random.PRNGKey(3), 20, 9, (32, 32))
    d = str(tmp_path / "pol")
    save_policy(
        d, params, obs_size=20, n_actions=9, feature="compact",
        action="target_fraction", n_levels=9, hidden=(32, 32),
    )
    out, meta = load_policy(d, expect_obs_size=20, expect_n_actions=9)
    assert meta["version"] == 2
    assert meta["feature"] == "compact" and meta["grouped"] is False
    assert_tree_equal(params, out)


def test_policy_checkpoint_obs_mismatch_message(tmp_path):
    """A pre-hetero (obs 16) policy fails with a migration message, not a
    shape error."""
    from repro.core.rl.networks import policy_init
    from repro.training.checkpoint import load_policy, save_policy

    params = policy_init(jax.random.PRNGKey(0), 16, 9, (32,))
    d = str(tmp_path / "old")
    save_policy(
        d, params, obs_size=16, n_actions=9, feature="compact",
        action="target_fraction", n_levels=9, hidden=(32,),
    )
    with pytest.raises(ValueError, match="obs_size=16.*expects obs_size=20"):
        load_policy(d, expect_obs_size=20)
    with pytest.raises(ValueError, match="n_actions=9"):
        load_policy(d, expect_n_actions=27)


def test_policy_checkpoint_unversioned_rejected(tmp_path):
    """A raw param tree saved without the header (the pre-versioning format)
    is rejected with a clear migration message."""
    from repro.core.rl.networks import policy_init
    from repro.training.checkpoint import Checkpointer, load_policy

    params = policy_init(jax.random.PRNGKey(0), 16, 9, (32,))
    d = str(tmp_path / "legacy")
    Checkpointer(d).save(0, params)  # headerless, as the old code did
    with pytest.raises(ValueError, match="predates checkpoint versioning"):
        load_policy(d)


def test_crash_restart_training_equivalence(tmp_path):
    """5 straight steps == 3 steps + crash + resume 2: identical params.

    Deterministic data addressing + checkpointed (params, opt, step) is the
    whole training state, so the restarted trajectory is bit-identical."""
    from repro.configs import get_arch
    from repro.data.pipeline import TokenStream
    from repro.models import build_model
    from repro.training.train_step import (
        TrainStepConfig,
        make_optimizer,
        make_train_step,
    )

    cfg = get_arch("internlm2-1.8b", reduced=True)
    model = build_model(cfg)
    opt = make_optimizer("adamw", 1e-3)
    step_fn = jax.jit(make_train_step(model, opt, TrainStepConfig()))
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=0)

    def run(params, opt_state, start, n):
        for i in range(start, start + n):
            params, opt_state, _ = step_fn(params, opt_state, stream.batch_at(i))
        return params, opt_state

    p0 = model.init(jax.random.PRNGKey(0))
    o0 = opt.init(p0)

    pA, oA = run(p0, o0, 0, 5)

    ck = Checkpointer(str(tmp_path))
    pB, oB = run(p0, o0, 0, 3)
    ck.save(3, {"p": pB, "o": oB})
    step, state = ck.restore(jax.eval_shape(lambda: {"p": pB, "o": oB}))
    pB, oB = run(state["p"], state["o"], step, 2)

    for a, b in zip(jax.tree_util.tree_leaves(pA), jax.tree_util.tree_leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
