"""Layer-level correctness: chunked-vs-naive attention, GLA chunk-vs-scan,
MoE dispatch vs dense oracle, cache decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

RNG = np.random.default_rng(1)


def test_chunked_attention_equals_naive():
    q = jnp.asarray(RNG.normal(size=(2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 256, 2, 32)), jnp.float32)
    a = L.attention_naive(q, k, v, causal=True)
    b = L.attention_chunked(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_chunked_attention_q_offset():
    q = jnp.asarray(RNG.normal(size=(1, 64, 2, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 128, 2, 16)), jnp.float32)
    a = L.attention_naive(q, k, v, causal=True, q_offset=64)
    b = L.attention_chunked(q, k, v, causal=True, q_offset=64, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_gla_chunked_equals_scan():
    q = jnp.asarray(RNG.normal(size=(2, 192, 2, 24)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 192, 2, 24)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 192, 2, 48)), jnp.float32)
    g = jnp.asarray(-np.abs(RNG.normal(size=(2, 192, 2)) * 0.1), jnp.float32)
    y1, h1 = S.gla_scan_reference(q, k, v, g)
    y2, h2 = S.chunked_gla(q, k, v, g, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5, rtol=2e-5)


def test_gla_initial_state_threading():
    """Chunked with h0 == scan with h0 (prefill-with-state path)."""
    b, s, h, dk, dv = 1, 128, 2, 16, 16
    q = jnp.asarray(RNG.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, dk)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, dv)), jnp.float32)
    g = jnp.asarray(-np.abs(RNG.normal(size=(b, s, h)) * 0.1), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(b, h, dk, dv)), jnp.float32)
    y1, hT1 = S.gla_scan_reference(q, k, v, g, h0)
    y2, hT2 = S.chunked_gla(q, k, v, g, h0, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(hT1), np.asarray(hT2), atol=2e-5, rtol=2e-5)


def test_mamba2_decode_matches_prefill():
    """Token-by-token decode == one-shot forward, via carried state."""
    dims = S.Mamba2Dims.make(d_model=32, d_state=16, expand=2, head_dim=16)
    p = S.mamba2_init(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 24, 32)), jnp.float32)
    y_full, _ = S.mamba2_apply(p, x, dims, chunk=8)
    hs, (cxs, cbcs) = S.mamba2_state_shape(dims, 2)
    state = (
        jnp.zeros(hs, jnp.float32),
        (jnp.zeros(cxs, jnp.float32), jnp.zeros(cbcs, jnp.float32)),
    )
    ys = []
    for t in range(24):
        y_t, state = S.mamba2_decode(p, x[:, t], dims, state)
        ys.append(y_t)
    y_dec = jnp.stack(ys, 1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_dec), atol=2e-4, rtol=2e-3
    )


def test_mlstm_decode_matches_prefill():
    dims = S.MLstmDims.make(d_model=32, n_heads=2, expand=2)
    p = S.mlstm_init(jax.random.PRNGKey(1), dims, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 16, 32)), jnp.float32)
    y_full, _ = S.mlstm_apply(p, x, dims, chunk=4)
    hs, ns = S.mlstm_state_shape(dims, 2)
    state = (jnp.zeros(hs, jnp.float32), jnp.zeros(ns, jnp.float32))
    ys = []
    for t in range(16):
        y_t, state = S.mlstm_decode(p, x[:, t], dims, state)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(jnp.stack(ys, 1)), atol=2e-4, rtol=2e-3
    )


def test_slstm_decode_matches_apply():
    dims = S.SLstmDims.make(d_model=16, n_heads=2)
    p = S.slstm_init(jax.random.PRNGKey(2), dims, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 12, 16)), jnp.float32)
    y_full, _ = S.slstm_apply(p, x, dims)
    state = S.slstm_zero_state(dims, 2)
    ys = []
    for t in range(12):
        y_t, state = S.slstm_decode(p, x[:, t], dims, state)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(jnp.stack(ys, 1)), atol=2e-5, rtol=2e-5
    )


def test_moe_dispatch_matches_dense_oracle():
    """Ample capacity: sorted-dispatch path == dense every-expert oracle."""
    d, e, dff = 16, 8, 32
    p = M.moe_init(jax.random.PRNGKey(3), d, e, dff, 1, 32, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 32, d)), jnp.float32)
    y, aux = M.moe_apply(p, x, n_experts=e, top_k=2, capacity_factor=8.0)
    y_ref = M.moe_apply_reference(p, x, n_experts=e, top_k=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5, rtol=2e-4)
    assert float(aux) >= 1.0 - 1e-3  # Switch aux >= 1 at uniform routing


def test_moe_capacity_drops_tokens():
    """Tight capacity: output differs from oracle only on dropped tokens
    (residual path), never NaN."""
    d, e, dff = 8, 4, 16
    p = M.moe_init(jax.random.PRNGKey(4), d, e, dff, 0, 0, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(1, 64, d)), jnp.float32)
    y, _ = M.moe_apply(p, x, n_experts=e, top_k=2, capacity_factor=0.25)
    assert np.isfinite(np.asarray(y)).all()


def test_rope_rotation_preserves_norm():
    x = jnp.asarray(RNG.normal(size=(2, 16, 4, 32)), jnp.float32)
    pos = jnp.arange(16)
    y = L.apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 1, 1, 32)), jnp.float32)
    def dot_at(pq, pv):
        rq = L.apply_rope(q, jnp.asarray([pq]), 10000.0)
        rv = L.apply_rope(v, jnp.asarray([pv]), 10000.0)
        return float(jnp.sum(rq * rv))
    assert dot_at(3, 7) == pytest.approx(dot_at(10, 14), rel=1e-4)


def test_kv_cache_attention_matches_full():
    """attn_apply with cache (prefill then one decode step) == full attn."""
    d_model, h, kh, hd = 32, 4, 2, 8
    p = L.attn_init(jax.random.PRNGKey(5), d_model, h, kh, hd, False, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 17, d_model)), jnp.float32)
    full, _ = L.attn_apply(
        p, x, n_heads=h, n_kv_heads=kh, head_dim=hd,
        positions=jnp.arange(17), theta=1e4, causal=True,
    )
    cache = (
        jnp.zeros((2, 32, kh, hd), jnp.float32),
        jnp.zeros((2, 32, kh, hd), jnp.float32),
    )
    out_pre, cache = L.attn_apply(
        p, x[:, :16], n_heads=h, n_kv_heads=kh, head_dim=hd,
        positions=jnp.arange(16), theta=1e4, causal=True,
        cache=cache, cache_pos=jnp.asarray(0),
    )
    out_dec, cache = L.attn_apply(
        p, x[:, 16:17], n_heads=h, n_kv_heads=kh, head_dim=hd,
        positions=jnp.arange(16, 17), theta=1e4, causal=True,
        cache=cache, cache_pos=jnp.asarray(16),
    )
    np.testing.assert_allclose(
        np.asarray(full[:, :16]), np.asarray(out_pre), atol=2e-5, rtol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(full[:, 16:]), np.asarray(out_dec), atol=2e-5, rtol=2e-5
    )
