"""Multi-device distribution tests (subprocess with fake devices, so the
main pytest process keeps the 1-device view required by the smoke tests)."""
import textwrap

import pytest

from conftest import run_subprocess


def test_pipeline_parallel_matches_sequential():
    run_subprocess(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.training.pipeline import pipeline_forward, split_stages, make_stage_fn
            mesh = jax.make_mesh((4, 2), ("pod", "data"))
            L, D = 8, 16
            rng = np.random.default_rng(0)
            w = jnp.asarray(rng.normal(size=(L, D, D)) * 0.1, jnp.float32)
            block = lambda lp, x: jnp.tanh(x @ lp)
            x = jnp.asarray(rng.normal(size=(6, 3, D)), jnp.float32)
            out = pipeline_forward(make_stage_fn(block), split_stages(w, 4), x, mesh=mesh, axis="pod")
            ref = x
            for i in range(L):
                ref = jnp.tanh(ref @ w[i])
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
            print("OK")
            """
        ),
        n_devices=8,
    )


def test_data_parallel_train_step_matches_single_device():
    """DP over 4 devices == single-device step (same global batch)."""
    run_subprocess(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs import get_arch
            from repro.models import build_model
            from repro.models.sharding import batch_shardings, params_shardings
            from repro.training.train_step import TrainStepConfig, make_optimizer, make_train_step

            cfg = get_arch("internlm2-1.8b", reduced=True).replace(remat=False)
            model = build_model(cfg)
            opt = make_optimizer("adamw", 1e-3)
            step = make_train_step(model, opt, TrainStepConfig())
            params = model.init(jax.random.PRNGKey(0))
            batch = {"tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}

            p1, _, m1 = jax.jit(step)(params, opt.init(params), batch)

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            with mesh:
                p_sh = params_shardings(cfg, mesh, jax.eval_shape(lambda: params))
                b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch), 8)
                params_d = jax.tree_util.tree_map(jax.device_put, params, p_sh)
                batch_d = jax.tree_util.tree_map(jax.device_put, batch, b_sh)
                pN, _, mN = jax.jit(step)(params_d, opt.init(params_d), batch_d)

            assert abs(float(m1["loss"]) - float(mN["loss"])) < 1e-4, (m1, mN)
            for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(pN)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    atol=5e-3, rtol=5e-3)
            print("OK")
            """
        ),
        n_devices=8,
    )


def test_elastic_reshard_preserves_values():
    run_subprocess(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp, numpy as np, functools
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.training.elastic import reshard, surviving_mesh

            tree = {"a": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((3,))}
            fn = lambda mesh, shapes: jax.tree_util.tree_map(
                lambda s: NamedSharding(
                    mesh, P("data", None) if len(s.shape) == 2 else P()), shapes)
            m8 = surviving_mesh(8, 1)
            t8 = reshard(tree, m8, fn)
            m4 = surviving_mesh(4, 1)   # half the fleet died
            t4 = reshard(t8, m4, fn)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(t4[k]), np.asarray(tree[k]))
            m8b = surviving_mesh(8, 1)  # nodes came back
            t8b = reshard(t4, m8b, fn)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(t8b[k]), np.asarray(tree[k]))
            print("OK")
            """
        ),
        n_devices=8,
    )


def test_rl_envs_shard_over_data_axis():
    """The paper's RL loop vmapped over envs, sharded over 'data'."""
    run_subprocess(
        textwrap.dedent(
            """
            import functools, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core.engine import init_state, make_const
            from repro.core.rl.env import EnvConfig, env_reset, env_step
            from repro.core.types import BasePolicy, EngineConfig, PSMVariant
            from repro.workloads.generator import GeneratorConfig, generate_workload
            from repro.workloads.platform import PlatformSpec

            plat = PlatformSpec(nb_nodes=16)
            wl = generate_workload(GeneratorConfig(n_jobs=24, nb_res=16, seed=0))
            cfg = EnvConfig(engine=EngineConfig(
                psm=PSMVariant.RL, base=BasePolicy.EASY, rl_decision_interval=600))
            const = make_const(plat, cfg.engine)
            sim0 = init_state(plat, wl, cfg.engine)
            E = 16
            sims = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (E,) + a.shape), sim0)
            mesh = jax.make_mesh((8,), ("data",))
            shard = lambda t: jax.tree_util.tree_map(
                lambda x: jax.device_put(
                    x, NamedSharding(mesh, P(*(("data",) + (None,) * (x.ndim - 1))))),
                t)
            with mesh:
                sims = shard(sims)
                states, obs = jax.jit(jax.vmap(functools.partial(env_reset, cfg, const)))(sims)
                step = jax.jit(jax.vmap(functools.partial(env_step, cfg, const)))
                states, obs, r, done, info = step(states, jnp.zeros((E,), jnp.int32))
            assert obs.shape == (E, cfg.obs_size)
            print("OK")
            """
        ),
        n_devices=8,
    )


def test_dryrun_single_cell():
    """One full-size dry-run cell lowers + compiles on the 16x16 mesh."""
    run_subprocess(
        textwrap.dedent(
            """
            from repro.launch.dryrun import lower_cell
            rec = lower_cell("whisper-tiny", "decode_32k", multi_pod=False)
            assert rec["status"] == "ok", rec
            assert rec["flops_per_device"] > 0
            assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
            print("OK", rec["roofline"]["dominant"])
            """
        ),
        n_devices=512,
        timeout=900,
    )


def test_hlo_analysis_counts_scan_trips():
    """Trip-count-aware FLOP accounting vs hand-computed scan matmul."""
    run_subprocess(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.hlo_analysis import analyze_hlo
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            def step(w, x):
                def body(c, _):
                    return jnp.tanh(c @ w), ()
                y, _ = jax.lax.scan(body, x, None, length=3)
                return y.sum()
            w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
            x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
            with mesh:
                comp = jax.jit(step, in_shardings=(
                    NamedSharding(mesh, P(None, "model")),
                    NamedSharding(mesh, P("data", None)))).lower(w, x).compile()
            cost = analyze_hlo(comp.as_text(), 8)
            want = 3 * 2 * 128 * 128 * 512  # 3 trips x per-device dot
            assert abs(cost.flops - want) / want < 0.01, (cost.flops, want)
            assert cost.collective_counts.get("all-gather", 0) == 3.0
            print("OK")
            """
        ),
        n_devices=8,
    )
