"""Clean case: a miniature engine that satisfies every spars-lint rule —
all jitted-scope config reads ride the trace key, no raw flag gates, no
host effects in traced bodies."""


def _static_trace_key(platform, config, J, cap):
    return (config.window, config.terminate_overrun, J, cap)


def _scheduler_pass(s, const, cfg):
    width = cfg.window
    return s, width


def _start_jobs(s, const, cfg):
    if cfg.terminate_overrun:
        return s
    return s


def run_sim(s, const, cfg):
    s, _ = _scheduler_pass(s, const, cfg)
    return _start_jobs(s, const, cfg)
