"""Seeded SL001 violations: `cfg.shiny`, `cfg.forecast_alpha`, and
`cfg.devices` are read inside the jitted scope (reachable from run_sim)
but missing from _static_trace_key.

The forecast read seeds the rule-10 drift mode specifically: horizon/alpha
are TRACED EngineConst operands in the live tree, so a static `cfg.*` read
of them in jitted scope is exactly the bug SL001 exists to catch. The
devices read seeds the §Device-sharded sweeps drift mode: the device
count selects the compiled sharding, so an unkeyed read would let a
sharded grid silently reuse an unsharded program's cache entry."""


def _static_trace_key(platform, config, J, cap):
    return (config.window, J, cap)


def _scheduler_pass(s, const, cfg):
    width = cfg.window
    shiny = cfg.shiny
    return s, width, shiny


def apply_forecast(s, const, cfg):
    alpha = cfg.forecast_alpha
    return s, alpha


def _shard_rows(s, cfg):
    mesh_width = cfg.devices
    return s, mesh_width


def run_sim(s, const, cfg):
    s, _, _ = _scheduler_pass(s, const, cfg)
    s, _ = apply_forecast(s, const, cfg)
    s, _ = _shard_rows(s, cfg)
    return s
