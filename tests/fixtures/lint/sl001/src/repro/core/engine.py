"""Seeded SL001 violation: `cfg.shiny` is read inside the jitted scope
(reachable from run_sim) but missing from _static_trace_key."""


def _static_trace_key(platform, config, J, cap):
    return (config.window, J, cap)


def _scheduler_pass(s, const, cfg):
    width = cfg.window
    shiny = cfg.shiny
    return s, width, shiny


def run_sim(s, const, cfg):
    s, _, _ = _scheduler_pass(s, const, cfg)
    return s
