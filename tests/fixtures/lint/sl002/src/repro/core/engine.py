"""Seeded SL002 violations: raw PolicyParams flag reads in gate positions
instead of routing through static_bool — one classic (sleep_enabled), one
against the rule-10 forecast flags (this tree has no policy.py, so the
linter's DEFAULT_FLAGS fallback must know the forecast fields)."""


def _static_trace_key(platform, config, J, cap):
    return (J, cap)


def _power_step(s, const, pp):
    if pp.sleep_enabled:
        return s
    if pp.forecast_enabled and not pp.forecast_dvfs:
        return s
    return s


def run_sim(s, const, cfg):
    return _power_step(s, const, cfg)
