"""Seeded SL002 violation: a raw PolicyParams flag read in a gate position
instead of routing through static_bool."""


def _static_trace_key(platform, config, J, cap):
    return (J, cap)


def _power_step(s, const, pp):
    if pp.sleep_enabled:
        return s
    return s


def run_sim(s, const, cfg):
    return _power_step(s, const, cfg)
