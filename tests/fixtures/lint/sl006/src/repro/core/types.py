"""Seeded SL006 violation: a SimMetrics field that never reaches row()."""
from typing import NamedTuple


class SimMetrics(NamedTuple):
    total_energy_j: float
    secret_debug: float

    def row(self):
        return {"total_energy_j": self.total_energy_j}
