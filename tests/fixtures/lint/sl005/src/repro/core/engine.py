"""Seeded SL005 violations: host numpy, a Python bool() on a traced value,
and print() inside a jit-traced body."""
import numpy as np


def _static_trace_key(platform, config, J, cap):
    return (J, cap)


def accrue_energy(s, const, cfg):
    total = np.sum(s.energy)
    if bool(s.truncated):
        print("truncated", total)
    return s


def run_sim(s, const, cfg):
    return accrue_energy(s, const, cfg)
