"""Seeded SL003 violation (oracle side): a PyDES method with no engine
rule twin."""


class PyDES:
    def __init__(self):
        pass

    def run(self):
        return None

    def _unmatched_rule(self):
        return None
