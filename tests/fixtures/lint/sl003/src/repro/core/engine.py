"""Seeded SL003 violation: an s-first engine rule with no PyDES twin."""


def _static_trace_key(platform, config, J, cap):
    return (J, cap)


def frobnicate(s, const):
    return s


def run_sim(s, const, cfg):
    return frobnicate(s, const)
