"""Seeded SL003 violations: s-first engine rules with no PyDES twin — one
generic (frobnicate) and one spelled exactly like the live rule-10 hook
(apply_forecast), seeding the one-sided-forecast drift mode: the oracle
tree next door has no _apply_forecast method."""


def _static_trace_key(platform, config, J, cap):
    return (J, cap)


def frobnicate(s, const):
    return s


def apply_forecast(s, const):
    return s


def run_sim(s, const, cfg):
    return frobnicate(s, const)
