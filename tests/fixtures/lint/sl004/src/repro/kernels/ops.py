"""Seeded SL004 violation: a Pallas wrapper with no reference fallback and
no zero-size short-circuit."""
from repro.kernels import ref  # noqa: F401
from repro.kernels.frob import frob as _frob_kernel


def frob(x, *, block: int = 128):
    return _frob_kernel(x, block=block)
