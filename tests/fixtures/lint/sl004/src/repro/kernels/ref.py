"""Reference module for the sl004 fixture — deliberately has no
frob_reference, so the wrapper has nothing to fall back to."""


def unrelated_reference(x):
    return x
