"""Waiver case: the same host-numpy read that fires SL005 in the sl005
fixture, silenced by an ignore comment (comma-list form) in the comment
block above the flagged line."""
import numpy as np


def _static_trace_key(platform, config, J, cap):
    return (J, cap)


def accrue_energy(s, const, cfg):
    # a host-side constant lookup table, folded at trace time on purpose
    # spars-lint: ignore[SL005,SL001] intentional trace-time constant fold
    lut = np.arange(8)
    return s, lut


def run_sim(s, const, cfg):
    return accrue_energy(s, const, cfg)[0]
