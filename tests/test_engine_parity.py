"""JAX engine vs sequential Python oracle: exact schedule parity and
energy agreement across all six paper schedulers (paper §3.1 validation —
the Batsim comparison analogue, here with a bit-exact semantic oracle)."""
import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import (
    NodeGroup,
    PlatformSpec,
    mixed_platform_example,
    platform_from_groups,
)

SCHEDULERS = [
    (base, psm)
    for base in (BasePolicy.FCFS, BasePolicy.EASY)
    for psm in (PSMVariant.PSUS, PSMVariant.PSAS, PSMVariant.PSAS_IPM)
]

# 3-group mixed platform: different idle/sleep watts, asymmetric t_on/t_off,
# speeds 2x / 0.5x / 1x (core/SEMANTICS.md §Heterogeneity)
hetero_platform = mixed_platform_example


@pytest.mark.parametrize("base,psm", SCHEDULERS)
@pytest.mark.parametrize("seed", [0, 3])
def test_schedule_parity(base, psm, seed):
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(
        GeneratorConfig(n_jobs=100, nb_res=16, seed=seed, overrun_prob=0.2)
    )
    cfg = EngineConfig(base=base, psm=psm, timeout=300, terminate_overrun=True)
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)

    # exact schedule equality (same tie-breaking rules on both engines)
    tab_jax = schedule_table(s)
    tab_ref = des.schedule_table()
    np.testing.assert_array_equal(tab_jax, tab_ref)

    # energy: f32 Kahan vs f64 oracle
    m_jax = metrics_from_state(s, plat.power_active)
    assert m_jax.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    assert m_jax.wasted_energy_j == pytest.approx(m_ref.wasted_energy_j, rel=1e-5)
    assert m_jax.mean_wait_s == pytest.approx(m_ref.mean_wait_s, rel=1e-6, abs=1e-6)
    assert m_jax.makespan_s == m_ref.makespan_s
    assert m_jax.n_terminated == m_ref.n_terminated


@pytest.mark.parametrize("base,psm", SCHEDULERS)
@pytest.mark.parametrize("node_order", ["cheap", "id"])
def test_heterogeneous_schedule_parity(base, psm, node_order):
    """All six schedulers on a 3-group mixed platform: exact schedule tables
    and energy agreement between the JAX engine and the sequential oracle,
    under both node orderings."""
    plat = hetero_platform(16)
    wl = generate_workload(
        GeneratorConfig(n_jobs=80, nb_res=16, seed=3, overrun_prob=0.2)
    )
    cfg = EngineConfig(
        base=base, psm=psm, timeout=200, terminate_overrun=True,
        node_order=node_order,
    )
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)

    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())

    m_jax = metrics_from_state(s, plat)
    assert m_jax.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    assert m_jax.wasted_energy_j == pytest.approx(m_ref.wasted_energy_j, rel=1e-5)
    assert m_jax.makespan_s == m_ref.makespan_s
    assert m_jax.n_terminated == m_ref.n_terminated
    # per-group ledgers agree too (f32 Kahan vs f64)
    assert len(m_jax.energy_by_group_j) == 3
    for g_jax, g_ref in zip(m_jax.energy_by_group_j, m_ref.energy_by_group_j):
        for e_jax, e_ref in zip(g_jax, g_ref):
            assert e_jax == pytest.approx(e_ref, rel=1e-4, abs=1.0)


@pytest.mark.slow
@pytest.mark.parametrize("base,psm", SCHEDULERS)
@pytest.mark.parametrize("seed", [1, 8])
def test_heterogeneous_parity_sweep(base, psm, seed):
    """Larger heterogeneous parity sweep (more jobs, second RNG stream)."""
    plat = hetero_platform(24)
    wl = generate_workload(
        GeneratorConfig(n_jobs=200, nb_res=24, seed=seed, overrun_prob=0.25)
    )
    cfg = EngineConfig(
        base=base, psm=psm, timeout=300, terminate_overrun=True,
        node_order="cheap",
    )
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


def test_cheap_order_prefers_low_energy_nodes():
    """With the expensive-per-work group at the low node ids,
    node_order="cheap" routes work to the cheap/fast group instead, so the
    ACTIVE-state energy (joules actually spent computing) drops vs "id"
    order. (Total energy also depends on idle/transition dynamics, which the
    order key deliberately does not model.)"""
    import dataclasses

    plat = platform_from_groups(
        (
            # 200 J per unit work — first by id, last by order_key
            NodeGroup(count=8, name="eco", power_active=100.0,
                      power_idle=80.0, power_sleep=4.0,
                      power_switch_on=100.0, power_switch_off=4.0,
                      t_switch_on=120, t_switch_off=180, speed=0.5),
            # 150 J per unit work — last by id, first by order_key
            NodeGroup(count=8, name="fast", power_active=300.0,
                      power_idle=250.0, power_sleep=12.0,
                      power_switch_on=300.0, power_switch_off=12.0,
                      t_switch_on=120, t_switch_off=180, speed=2.0),
        )
    )
    wl = generate_workload(
        GeneratorConfig(n_jobs=60, nb_res=16, seed=5, max_res=4)
    )
    cfg_id = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSAS,
                          timeout=200, node_order="id")
    cfg_cheap = dataclasses.replace(cfg_id, node_order="cheap")
    m_id = metrics_from_state(engine.simulate(plat, wl, cfg_id), plat)
    m_cheap = metrics_from_state(engine.simulate(plat, wl, cfg_cheap), plat)
    ACTIVE = 3
    assert m_cheap.energy_by_state_j[ACTIVE] < m_id.energy_by_state_j[ACTIVE]


@pytest.mark.parametrize("timeout", [60, 900, None])
def test_timeout_sweep_parity(timeout):
    plat = PlatformSpec(nb_nodes=32)
    wl = generate_workload(GeneratorConfig(n_jobs=60, nb_res=32, seed=11))
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSAS_IPM, timeout=timeout
    )
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat.power_active)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


def test_always_on_baseline():
    """PSM=NONE: nodes never sleep; energy = N * P * makespan-ish."""
    plat = PlatformSpec(nb_nodes=8)
    wl = generate_workload(GeneratorConfig(n_jobs=30, nb_res=8, seed=5))
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.NONE)
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    m = metrics_from_state(s, plat.power_active)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    # never any sleep or transition energy
    assert m.energy_by_state_j[0] == 0.0
    assert m.energy_by_state_j[1] == 0.0
    assert m.energy_by_state_j[4] == 0.0


def test_vmapped_timeout_sweep_matches_scalar():
    """One compiled program sweeping timeouts == per-timeout runs."""
    import functools

    import jax
    import jax.numpy as jnp

    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=50, nb_res=16, seed=2))
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=300)
    s0 = engine.init_state(plat, wl, cfg)
    const = engine.make_const(plat, cfg)
    timeouts = jnp.asarray([60, 300, 1800], jnp.int32)
    consts = jax.vmap(lambda t: const._replace(timeout=t))(timeouts)
    batched = jax.vmap(lambda c: engine.run_sim(s0, c, cfg))(consts)
    for i, t in enumerate([60, 300, 1800]):
        single = engine.simulate(
            plat, wl, EngineConfig(base=cfg.base, psm=cfg.psm, timeout=t)
        )
        np.testing.assert_allclose(
            np.asarray(batched.energy[i]), np.asarray(single.energy), rtol=1e-6
        )
