"""Property tests on the engine's invariants (DESIGN.md §6).

Hypothesis is optional: when installed, the strategies below fuzz workloads
and configs; when absent the same properties still *execute* (not skip)
against a deterministic seeded corpus drawn from the identical
distributions — so the invariants are always enforced, and installing
hypothesis only widens the search.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import engine
from repro.core.metrics import metrics_from_state, np_state
from repro.core.ref.pydes import run_pydes
from repro.core.types import (
    ACTIVE,
    DONE,
    BasePolicy,
    EngineConfig,
    PSMVariant,
)
from repro.workloads.platform import NodeGroup, PlatformSpec, platform_from_groups
from repro.workloads.workload import workload_from_arrays

N_NODES = 8

PLAT = PlatformSpec(nb_nodes=N_NODES, t_switch_on=120, t_switch_off=180)
HET_PLAT = platform_from_groups(
    (
        NodeGroup(count=3, name="fast", power_active=300.0, power_idle=250.0,
                  power_sleep=12.0, power_switch_on=300.0,
                  power_switch_off=12.0, t_switch_on=60, t_switch_off=90,
                  speed=2.0),
        NodeGroup(count=3, name="eco", power_active=100.0, power_idle=80.0,
                  power_sleep=4.0, power_switch_on=100.0,
                  power_switch_off=4.0, t_switch_on=240, t_switch_off=300,
                  speed=0.5),
        NodeGroup(count=2, name="std", t_switch_on=120, t_switch_off=180),
    )
)

_BASES = [BasePolicy.FCFS, BasePolicy.EASY]
_PSMS = [PSMVariant.PSUS, PSMVariant.PSAS, PSMVariant.PSAS_IPM]
_TIMEOUTS = [None, 30, 600]


# -- one sample distribution, two drivers ------------------------------------
#
# _draw_* consume an np.random.Generator, so the seeded-corpus fallback and
# the hypothesis strategies sample the same space.

def _draw_workload(rng, max_jobs=18):
    n = int(rng.integers(1, max_jobs + 1))
    res = rng.integers(1, N_NODES + 1, n)
    subtime = np.sort(rng.integers(0, 5001, n))
    runtime = rng.integers(1, 4001, n)
    over = rng.integers(-50, 301, n)
    reqtime = np.maximum(1, runtime + over)
    return workload_from_arrays(
        res.tolist(), subtime.tolist(), runtime.tolist(), reqtime.tolist(),
        nb_res=N_NODES,
    )


def _draw_config(rng):
    return EngineConfig(
        base=_BASES[int(rng.integers(len(_BASES)))],
        psm=_PSMS[int(rng.integers(len(_PSMS)))],
        timeout=_TIMEOUTS[int(rng.integers(len(_TIMEOUTS)))],
        terminate_overrun=bool(rng.integers(2)),
        node_order=("id", "cheap")[int(rng.integers(2))],
        grouped_tables=bool(rng.integers(2)),
    )


def _corpus(tag: str, n: int, max_jobs=18):
    """Deterministic (wl, cfg) cases; seed derived from the test name."""
    # str hash() is salted per process, so derive the seed arithmetically
    base = sum(ord(c) for c in tag)
    out = []
    for i in range(n):
        rng = np.random.default_rng(10_000 * base + i)
        out.append((_draw_workload(rng, max_jobs), _draw_config(rng)))
    return out


if HAVE_HYPOTHESIS:

    @st.composite
    def workloads(draw, max_jobs=18):
        seed = draw(st.integers(0, 2**31 - 1))
        return _draw_workload(np.random.default_rng(seed), max_jobs)

    @st.composite
    def configs(draw):
        return EngineConfig(
            base=draw(st.sampled_from(_BASES)),
            psm=draw(st.sampled_from(_PSMS)),
            timeout=draw(st.sampled_from(_TIMEOUTS)),
            terminate_overrun=draw(st.booleans()),
            node_order=draw(st.sampled_from(["id", "cheap"])),
            grouped_tables=draw(st.booleans()),
        )


def property_test(tag: str, n_fallback: int, max_jobs=18, max_examples=25):
    """Run the decorated ``f(wl, cfg)`` under hypothesis when available,
    else over the deterministic corpus."""

    def wrap(f):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(wl=workloads(max_jobs=max_jobs), cfg=configs())(f)
            )
        cases = _corpus(tag, n_fallback, max_jobs)

        @pytest.mark.parametrize("case", range(n_fallback))
        def runner(case):
            wl, cfg = cases[case]
            f(wl, cfg)

        runner.__name__ = f.__name__
        runner.__doc__ = f.__doc__
        return runner

    return wrap


# -- properties ---------------------------------------------------------------


@property_test("invariants", n_fallback=10, max_examples=40)
def test_engine_invariants(wl, cfg):
    s = engine.simulate(PLAT, wl, cfg)
    d = np_state(s)
    exists = d["job_exists"]

    # every real job completed
    assert (d["job_status"][exists] == DONE).all()

    # no job started before submission
    started = d["job_start"] >= 0
    assert (
        d["job_start"][started & exists] >= d["job_subtime"][started & exists]
    ).all()

    # finish = start + effective runtime
    np.testing.assert_array_equal(
        d["job_finish"][exists & started],
        d["job_start"][exists & started] + d["job_eff"][exists & started],
    )

    # terminate-overrun semantics
    if cfg.terminate_overrun:
        assert (d["job_eff"][exists] <= d["job_reqtime"][exists]).all()

    # energy bookkeeping: total = sum over group x state, all >= 0,
    # and the per-group breakdown tiles the total exactly
    m = metrics_from_state(s, PLAT)
    assert m.total_energy_j >= 0
    assert m.total_energy_j == pytest.approx(
        sum(m.energy_by_state_j), rel=1e-5, abs=1e-3
    )
    assert m.total_energy_j == pytest.approx(
        sum(sum(g) for g in m.energy_by_group_j), rel=1e-5, abs=1e-3
    )
    assert m.wasted_energy_j <= m.total_energy_j + 1e-6

    # ACTIVE energy == power_active * sum(job runtimes * res)
    node_seconds = float(
        np.sum(d["job_eff"][exists & started] * d["job_res"][exists & started])
    )
    active_j = m.energy_by_state_j[ACTIVE]
    assert active_j == pytest.approx(
        PLAT.power_active * node_seconds, rel=1e-4, abs=1e-3
    )

    # all nodes released at the end
    assert (d["node_job"] == -1).all()


@property_test("parity", n_fallback=8, max_jobs=14)
def test_property_parity_with_oracle(wl, cfg):
    """Random workloads: JAX engine == Python oracle, schedules and energy."""
    from repro.core.metrics import schedule_table

    s = engine.simulate(PLAT, wl, cfg)
    m_ref, des = run_pydes(PLAT, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, PLAT)
    assert m.total_energy_j == pytest.approx(
        m_ref.total_energy_j, rel=1e-5, abs=1e-3
    )


@property_test("hetero-parity", n_fallback=6, max_jobs=12)
def test_property_parity_heterogeneous(wl, cfg):
    """Same parity property on a 3-group mixed platform (different watts,
    asymmetric transition delays, 0.5x/1x/2x speeds)."""
    from repro.core.metrics import schedule_table

    s = engine.simulate(HET_PLAT, wl, cfg)
    m_ref, des = run_pydes(HET_PLAT, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, HET_PLAT)
    assert m.total_energy_j == pytest.approx(
        m_ref.total_energy_j, rel=1e-5, abs=1e-3
    )
    assert m.total_energy_j == pytest.approx(
        sum(sum(g) for g in m.energy_by_group_j), rel=1e-5, abs=1e-3
    )


def _check_no_double_allocation(wl):
    """Step the engine manually; at every batch a node belongs to <= 1 job
    and RUNNING jobs hold exactly res nodes."""
    import jax

    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSAS_IPM, timeout=60)
    s = engine.init_state(PLAT, wl, cfg)
    const = engine.make_const(PLAT, cfg)
    step = jax.jit(
        lambda s: engine.process_batch(
            engine.accrue_energy(s, engine.next_time(s, const, cfg), const)._replace(
                t=engine.next_time(s, const, cfg)
            ),
            const,
            cfg,
        )
    )
    s = engine.process_batch(s, const, cfg)
    for _ in range(200):
        d = np_state(s)
        nj = d["node_job"]
        # a node maps to one job by construction; check job->node counts
        running = np.nonzero((d["job_status"] == 2) & d["job_exists"])[0]
        for j in running:
            assert (nj == j).sum() == d["job_res"][j]
        if (d["job_status"][d["job_exists"]] == DONE).all():
            break
        nt = engine.next_time(s, const, cfg)
        if int(nt) >= int(2**30):
            break
        s = step(s)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(wl=workloads(max_jobs=10))
    def test_no_double_allocation_trace(wl):
        _check_no_double_allocation(wl)

else:

    @pytest.mark.parametrize("case", range(4))
    def test_no_double_allocation_trace(case):
        rng = np.random.default_rng(42_000 + case)
        _check_no_double_allocation(_draw_workload(rng, max_jobs=10))
