"""Hypothesis property tests on the engine's invariants (DESIGN.md §6)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import engine
from repro.core.metrics import metrics_from_state, np_state
from repro.core.ref.pydes import run_pydes
from repro.core.types import (
    ACTIVE,
    DONE,
    BasePolicy,
    EngineConfig,
    PSMVariant,
)
from repro.workloads.platform import PlatformSpec
from repro.workloads.workload import workload_from_arrays

# -- strategies --------------------------------------------------------------

N_NODES = 8


@st.composite
def workloads(draw, max_jobs=18):
    n = draw(st.integers(1, max_jobs))
    res = draw(
        st.lists(st.integers(1, N_NODES), min_size=n, max_size=n)
    )
    subtime = draw(
        st.lists(st.integers(0, 5000), min_size=n, max_size=n)
    )
    runtime = draw(st.lists(st.integers(1, 4000), min_size=n, max_size=n))
    over = draw(st.lists(st.integers(-50, 300), min_size=n, max_size=n))
    reqtime = [max(1, r + o) for r, o in zip(runtime, over)]
    return workload_from_arrays(
        res, sorted(subtime), runtime, reqtime, nb_res=N_NODES
    )


@st.composite
def configs(draw):
    return EngineConfig(
        base=draw(st.sampled_from([BasePolicy.FCFS, BasePolicy.EASY])),
        psm=draw(
            st.sampled_from(
                [PSMVariant.PSUS, PSMVariant.PSAS, PSMVariant.PSAS_IPM]
            )
        ),
        timeout=draw(st.sampled_from([None, 30, 600])),
        terminate_overrun=draw(st.booleans()),
    )


PLAT = PlatformSpec(nb_nodes=N_NODES, t_switch_on=120, t_switch_off=180)


# -- properties ---------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(wl=workloads(), cfg=configs())
def test_engine_invariants(wl, cfg):
    s = engine.simulate(PLAT, wl, cfg)
    d = np_state(s)
    exists = d["job_exists"]

    # every real job completed
    assert (d["job_status"][exists] == DONE).all()

    # no job started before submission
    started = d["job_start"] >= 0
    assert (d["job_start"][started & exists] >= d["job_subtime"][started & exists]).all()

    # finish = start + effective runtime
    np.testing.assert_array_equal(
        d["job_finish"][exists & started],
        d["job_start"][exists & started] + d["job_eff"][exists & started],
    )

    # terminate-overrun semantics
    if cfg.terminate_overrun:
        assert (d["job_eff"][exists] <= d["job_reqtime"][exists]).all()
    else:
        np.testing.assert_array_equal(
            d["job_eff"][exists],
            np.minimum(d["job_eff"][exists], d["job_eff"][exists]),
        )

    # energy bookkeeping: total = sum of per-state energies, all >= 0
    m = metrics_from_state(s, PLAT.power_active)
    assert m.total_energy_j >= 0
    assert m.total_energy_j == pytest_approx(sum(m.energy_by_state_j))
    assert m.wasted_energy_j <= m.total_energy_j + 1e-6

    # ACTIVE energy == power_active * sum(job runtimes * res)
    node_seconds = float(
        np.sum(d["job_eff"][exists & started] * d["job_res"][exists & started])
    )
    active_j = m.energy_by_state_j[ACTIVE]
    assert active_j == pytest_approx(PLAT.power_active * node_seconds, rel=1e-4)

    # all nodes released at the end
    assert (d["node_job"] == -1).all()


def pytest_approx(x, rel=1e-5):
    import pytest

    return pytest.approx(x, rel=rel, abs=1e-3)


@settings(max_examples=25, deadline=None)
@given(wl=workloads(max_jobs=14), cfg=configs())
def test_property_parity_with_oracle(wl, cfg):
    """Random workloads: JAX engine == Python oracle, schedules and energy."""
    from repro.core.metrics import schedule_table

    s = engine.simulate(PLAT, wl, cfg)
    m_ref, des = run_pydes(PLAT, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, PLAT.power_active)
    assert m.total_energy_j == pytest_approx(m_ref.total_energy_j)


@settings(max_examples=15, deadline=None)
@given(wl=workloads(max_jobs=10))
def test_no_double_allocation_trace(wl):
    """Step the engine manually; at every batch a node belongs to <= 1 job
    and RUNNING jobs hold exactly res nodes."""
    import jax

    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSAS_IPM, timeout=60)
    s = engine.init_state(PLAT, wl, cfg)
    const = engine.make_const(PLAT, cfg)
    step = jax.jit(
        lambda s: engine.process_batch(
            engine.accrue_energy(s, engine.next_time(s, const, cfg), const)._replace(
                t=engine.next_time(s, const, cfg)
            ),
            const,
            cfg,
        )
    )
    s = engine.process_batch(s, const, cfg)
    for _ in range(200):
        d = np_state(s)
        nj = d["node_job"]
        held = nj[nj >= 0]
        # a node maps to one job by construction; check job->node counts
        running = np.nonzero((d["job_status"] == 2) & d["job_exists"])[0]
        for j in running:
            assert (nj == j).sum() == d["job_res"][j]
        if (d["job_status"][d["job_exists"]] == DONE).all():
            break
        nt = engine.next_time(s, const, cfg)
        if int(nt) >= int(2**30):
            break
        s = step(s)
