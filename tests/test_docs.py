"""Documentation hygiene: the `make docs-check` lane, run in tier-1 too.

The checker (tools/docs_check.py) verifies dead links, stale file
references, code-fence balance, and that fenced `python -m` / `python
<file>` commands still resolve — so README/SEMANTICS/experiments docs
cannot silently rot when files move.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import docs_check  # noqa: E402


def test_repo_docs_are_clean():
    problems = docs_check.main()
    assert not problems, "\n".join(problems)


def test_checker_catches_rot(tmp_path):
    """The checker itself must detect each rot class (meta-test)."""
    bad = tmp_path / "bad.md"
    bad.write_text(
        "see [x](does/not/exist.md) and `src/gone/file.py`\n"
        "```sh\nPYTHONPATH=src python -m repro.launch.missing_mod\n```\n"
        "```\nunbalanced\n"
    )
    rel = os.path.relpath(str(bad), docs_check.REPO)
    problems = docs_check.main(docs=(rel,))
    text = "\n".join(problems)
    assert "dead link" in text
    assert "stale file reference" in text
    assert "missing module" in text
    assert "unbalanced" in text
