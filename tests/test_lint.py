"""spars-lint lane: the linter catches every seeded violation class, honors
waivers, and the live tree is clean — all in tier-1, so an invariant break
(a missed trace-key field, a raw flag gate, a one-sided rule, a kernel
without its fallback) fails the default `pytest` run, not just nightly.
"""
import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, "..", "tools", "lint"))

import spars_lint  # noqa: E402

FIXTURES = os.path.join(_HERE, "fixtures", "lint")
# fixture trees carry only source files, never the DOCS set, so the docs
# pass (SL007) is exercised against the live tree only
CODE_RULES = [r for r in spars_lint.RULE_IDS if r != "SL007"]


def _run(root, only):
    return spars_lint.run_passes(root=root, only=only)


@pytest.mark.parametrize("rule", CODE_RULES)
def test_seeded_violation_fires(rule):
    """Each rule's fixture tree produces >=1 finding of exactly that rule."""
    root = os.path.join(FIXTURES, rule.lower())
    findings = _run(root, only=[rule])
    assert findings, f"{rule} fixture produced no findings"
    assert all(f.rule == rule for f in findings)
    assert all(f.line > 0 and f.file for f in findings)


def test_sl001_names_the_missing_field():
    findings = _run(os.path.join(FIXTURES, "sl001"), only=["SL001"])
    text = "\n".join(f.msg for f in findings)
    assert "cfg.shiny" in text and "_static_trace_key" in text


def test_sl001_catches_unkeyed_forecast_read():
    """A static `cfg.forecast_alpha` read in jitted scope (rule 10 drift
    mode: horizon/alpha must ride EngineConst, not the config) is named."""
    findings = _run(os.path.join(FIXTURES, "sl001"), only=["SL001"])
    assert any("cfg.forecast_alpha" in f.msg for f in findings)


def test_sl001_catches_unkeyed_devices_read():
    """A static `cfg.devices` read in jitted scope (§Device-sharded
    sweeps drift mode: the device count selects the compiled sharding,
    so it must be part of the sweep cache key) is named."""
    findings = _run(os.path.join(FIXTURES, "sl001"), only=["SL001"])
    assert any("cfg.devices" in f.msg for f in findings)


def test_sl002_catches_raw_forecast_gates():
    """Both rule-10 flags fire through the DEFAULT_FLAGS fallback (the
    fixture tree carries no policy.py to introspect PolicyParams from)."""
    findings = _run(os.path.join(FIXTURES, "sl002"), only=["SL002"])
    text = "\n".join(f.msg for f in findings)
    assert ".forecast_enabled" in text
    assert ".forecast_dvfs" in text


def test_sl003_catches_one_sided_forecast_twin():
    """An engine-side `apply_forecast` with no PyDES._apply_forecast is a
    one-sided rule-10 — exactly the drift SL003 keeps two-sided."""
    findings = _run(os.path.join(FIXTURES, "sl003"), only=["SL003"])
    assert any(
        "`apply_forecast`" in f.msg and "PyDES.apply_forecast" in f.msg
        for f in findings
    )


def test_sl004_flags_both_contract_halves():
    findings = _run(os.path.join(FIXTURES, "sl004"), only=["SL004"])
    text = "\n".join(f.msg for f in findings)
    assert "zero-size" in text
    assert "ref.*_reference" in text


def test_waiver_silences_flagged_line():
    """An `ignore[SL005,SL001]` comma-list comment above the violation
    keeps the whole waived tree clean."""
    assert _run(os.path.join(FIXTURES, "waived"), only=CODE_RULES) == []


def test_clean_fixture_is_clean():
    assert _run(os.path.join(FIXTURES, "clean"), only=CODE_RULES) == []


def test_live_tree_is_clean():
    """All seven passes (SL001-SL006 + SL007 docs) over this repo."""
    findings = spars_lint.run_passes()
    assert not findings, "\n".join(f.render() for f in findings)


def test_cli_exit_codes():
    script = os.path.join(spars_lint.REPO, "tools", "lint", "spars_lint.py")
    bad = subprocess.run(
        [sys.executable, script, "--root",
         os.path.join(FIXTURES, "sl002"), "--only", "SL002"],
        capture_output=True, text=True,
    )
    assert bad.returncode == 1
    assert "SL002" in bad.stderr
    good = subprocess.run(
        [sys.executable, script, "--root",
         os.path.join(FIXTURES, "clean"), "--only", ",".join(CODE_RULES)],
        capture_output=True, text=True,
    )
    assert good.returncode == 0, good.stderr


def test_unknown_rule_rejected():
    with pytest.raises(SystemExit):
        spars_lint.run_passes(only=["SL999"])
