"""Heterogeneous-platform substrate: JSON schema round-trips, the
metamorphic homogeneous-as-per-node-entries guarantee, per-group energy
accounting, and the RL features' heterogeneity summary
(core/SEMANTICS.md §Heterogeneity)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state, np_state
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import (
    ACTIVE,
    NodeGroup,
    PlatformSpec,
    load_platform,
    mixed_platform_example,
    platform_from_groups,
)


def _state_entry(power_active=190.0, power_idle=190.0, power_sleep=9.0,
                 power_switch_on=190.0, power_switch_off=9.0,
                 t_on=120, t_off=180):
    return {
        "sleep": {"power": power_sleep},
        "idle": {"power": power_idle},
        "active": {"power": power_active},
        "switching_on": {"power": power_switch_on, "transition_time": t_on},
        "switching_off": {"power": power_switch_off, "transition_time": t_off},
    }


MIXED = mixed_platform_example(16)  # fast(5, 2.0x) / eco(5, 0.5x) / std(6)


# ------------------------------------------------------------- spec & loader

def test_node_tables_shapes_and_values():
    assert MIXED.nb_nodes == 16
    assert MIXED.is_heterogeneous
    assert MIXED.group_names() == ("fast", "eco", "std")
    table = MIXED.node_power_table()
    assert table.shape == (16, 5)
    assert table[0, ACTIVE] == 300.0 and table[5, ACTIVE] == 100.0
    assert table[15, ACTIVE] == 190.0
    np.testing.assert_array_equal(
        MIXED.node_group_id(), [0] * 5 + [1] * 5 + [2] * 6
    )
    np.testing.assert_array_equal(
        MIXED.node_t_switch_on(), [600] * 5 + [120] * 5 + [1800] * 6
    )
    np.testing.assert_array_equal(
        MIXED.node_speed(), np.asarray([2.0] * 5 + [0.5] * 5 + [1.0] * 6,
                                       np.float32)
    )
    # order key = active watts per unit work, float32
    key = MIXED.node_order_key()
    np.testing.assert_allclose(key[:5], 150.0)
    np.testing.assert_allclose(key[5:10], 200.0)
    np.testing.assert_allclose(key[10:], 190.0)


def test_group_counts_must_cover_nb_nodes():
    with pytest.raises(ValueError):
        PlatformSpec(nb_nodes=10, node_groups=(NodeGroup(count=4),))


def test_nonpositive_speed_rejected():
    with pytest.raises(ValueError):
        NodeGroup(count=2, speed=0.0)
    with pytest.raises(ValueError):
        PlatformSpec(nb_nodes=4, compute_speed=-1.0)
    with pytest.raises(ValueError):
        load_platform({"nb_nodes": 4, "compute_speed": 0})


def test_group_inherits_document_idle_power():
    """A group without its own idle power inherits the document-level idle,
    not its own active draw (consistent with every other state default)."""
    obj = {
        "states": {"active": {"power": 190.0}, "idle": {"power": 100.0}},
        "node_groups": [
            {"count": 2, "states": {"active": {"power": 300.0}}},
            {"count": 2, "states": _state_entry(power_active=100.0,
                                                power_idle=80.0)},
        ],
    }
    p = load_platform(obj)
    assert p.node_groups[0].power_idle == 100.0  # inherited, not 300
    assert p.node_groups[1].power_idle == 80.0  # own value kept
    # no document idle at all -> idle defaults to the entry's active draw
    q = load_platform(
        {"node_groups": [{"count": 2, "states": {"active": {"power": 300.0}}}]}
    )
    assert q.power_idle == 300.0


def test_heterogeneous_json_roundtrip(tmp_path):
    path = str(tmp_path / "platform.json")
    MIXED.save(path)
    loaded = load_platform(path)
    assert loaded.node_groups == MIXED.node_groups
    assert loaded.nb_nodes == MIXED.nb_nodes
    np.testing.assert_array_equal(
        loaded.node_power_table(), MIXED.node_power_table()
    )


def test_per_node_json_entries_preserved():
    """Distinct per-node entries survive loading (never silently collapsed)."""
    obj = {
        "nodes": [
            {"states": _state_entry(power_active=300.0), "compute_speed": 2.0},
            {"states": _state_entry(power_active=300.0), "compute_speed": 2.0},
            {"states": _state_entry(power_active=100.0), "compute_speed": 0.5},
            {"states": _state_entry()},
        ]
    }
    p = load_platform(obj)
    assert p.nb_nodes == 4
    assert p.is_heterogeneous
    assert [g.count for g in p.node_groups] == [2, 1, 1]
    assert p.node_power_table()[2, ACTIVE] == 100.0
    assert p.node_speed()[0] == 2.0 and p.node_speed()[2] == 0.5


def test_top_level_compute_speed_defaults_into_groups():
    """Document-level compute_speed applies to groups that don't set their
    own, matching the homogeneous loader's semantics."""
    obj = {
        "compute_speed": 2.0,
        "node_groups": [
            {"count": 2, "states": _state_entry(power_active=300.0)},
            {"count": 2, "compute_speed": 0.5,
             "states": _state_entry(power_active=100.0)},
        ],
    }
    p = load_platform(obj)
    np.testing.assert_array_equal(
        p.node_speed(), np.asarray([2.0, 2.0, 0.5, 0.5], np.float32)
    )


def test_identical_per_node_entries_collapse_to_scalar_spec():
    obj = {"nodes": [{"states": _state_entry()} for _ in range(8)]}
    p = load_platform(obj)
    assert p == PlatformSpec(nb_nodes=8, t_switch_on=120, t_switch_off=180)
    assert not p.node_groups  # fully collapsed to the scalar form


# ------------------------------------------------------------- metamorphic

@pytest.mark.parametrize("node_order", ["id", "cheap"])
def test_metamorphic_homogeneous_as_per_node_entries(node_order):
    """A homogeneous platform written as N identical per-node JSON entries
    must produce a bit-identical SimState to the scalar PlatformSpec path,
    and total energy must equal the sum of the per-group breakdowns."""
    scalar = PlatformSpec(nb_nodes=8, t_switch_on=120, t_switch_off=180)
    loaded = load_platform(
        {"nodes": [{"states": _state_entry()} for _ in range(8)]}
    )
    wl = generate_workload(GeneratorConfig(n_jobs=60, nb_res=8, seed=7))
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSAS, timeout=120,
        terminate_overrun=True, node_order=node_order,
    )
    s_scalar = engine.simulate(scalar, wl, cfg)
    s_loaded = engine.simulate(loaded, wl, cfg)
    for k, a in np_state(s_scalar).items():
        np.testing.assert_array_equal(
            a, np.asarray(getattr(s_loaded, k)), err_msg=k
        )

    m = metrics_from_state(s_loaded, loaded)
    assert len(m.energy_by_group_j) == 1
    assert m.total_energy_j == pytest.approx(
        sum(sum(g) for g in m.energy_by_group_j), rel=1e-6, abs=1e-3
    )


def test_group_energy_breakdown_tiles_total():
    """On a genuinely mixed platform the [G, 5] ledger tiles the total."""
    wl = generate_workload(GeneratorConfig(n_jobs=80, nb_res=16, seed=2))
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSAS_IPM, timeout=300,
        node_order="cheap",
    )
    s = engine.simulate(MIXED, wl, cfg)
    m = metrics_from_state(s, MIXED)
    assert len(m.energy_by_group_j) == 3
    assert m.group_names == ("fast", "eco", "std")
    assert m.total_energy_j == pytest.approx(
        sum(sum(g) for g in m.energy_by_group_j), rel=1e-6, abs=1e-3
    )
    # per-state totals are the group sums too
    for k in range(5):
        assert m.energy_by_state_j[k] == pytest.approx(
            sum(g[k] for g in m.energy_by_group_j), rel=1e-6, abs=1e-3
        )
    # every group accrued energy (all have nodes and the sim ran)
    assert all(sum(g) > 0 for g in m.energy_by_group_j)


# ------------------------------------------------------------- RL features

def test_hetero_features_flat_on_homogeneous_platform():
    from repro.core.rl.features import compact_features, feature_size

    plat = PlatformSpec(nb_nodes=8)
    wl = generate_workload(GeneratorConfig(n_jobs=10, nb_res=8, seed=0))
    cfg = EngineConfig(psm=PSMVariant.RL, base=BasePolicy.EASY)
    s = engine.init_state(plat, wl, cfg)
    const = engine.make_const(plat, cfg)
    s = engine.process_batch(s, const, cfg)
    f = np.asarray(compact_features(s, const))
    assert f.shape == (feature_size("compact"),)
    assert f[-4] == 0.0  # zero heterogeneity spread


def test_hetero_features_expose_power_speed_mix():
    from repro.core.rl.features import compact_features

    wl = generate_workload(GeneratorConfig(n_jobs=10, nb_res=16, seed=0))
    cfg = EngineConfig(psm=PSMVariant.RL, base=BasePolicy.EASY)
    s = engine.init_state(MIXED, wl, cfg)
    const = engine.make_const(MIXED, cfg)
    s = engine.process_batch(s, const, cfg)
    f = np.asarray(compact_features(s, const))
    spread = f[-4]
    assert 0.0 < spread <= 1.0
    assert np.isfinite(f).all()
