"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs,
plus prefill+decode consistency with the no-cache forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import build_model
from repro.training.train_step import TrainStepConfig, make_optimizer, make_train_step

ARCHS = list_archs()


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.n_image_embeds:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_image_embeds, cfg.d_model)), cfg.dtype
        )
    if cfg.encoder_layers:
        batch["encoder_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), cfg.dtype
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch)
    spec = {
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000, ssm_state=64),
        "glm4-9b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=151552),
        "qwen3-14b": dict(n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408, vocab_size=151936, qk_norm=True),
        "stablelm-3b": dict(n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912, vocab_size=50304),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92544),
        "xlstm-350m": dict(n_layers=24, d_model=1024, n_heads=4, vocab_size=50304),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, vocab_size=151936, n_experts=60, top_k=4, expert_d_ff=1408, n_shared_experts=4),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768, vocab_size=131072, n_experts=8, top_k=2),
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92553),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865, encoder_layers=4),
    }[arch]
    for k, v in spec.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    opt = make_optimizer(cfg.optimizer, 1e-3)
    step = jax.jit(make_train_step(model, opt, TrainStepConfig()))
    p2, o2, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    diff = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert diff > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = get_arch(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Greedy continuation via (prefill, decode) == slicing full forward."""
    cfg = get_arch(arch, reduced=True)
    if cfg.n_experts:
        # ample capacity: token-drop patterns depend on sequence length, so
        # dropping must be disabled to compare cached vs uncached paths
        cfg = cfg.replace(capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 24
    batch = make_batch(cfg, b=b, s=s, seed=3)

    logits_full, _ = jax.jit(model.forward)(params, batch)
    last_full = logits_full[:, -1]

    logits_pre, cache = jax.jit(
        lambda p, bt: model.prefill(p, bt, cache_len=s + 8)
    )(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]), np.asarray(last_full), atol=2e-2, rtol=2e-2
    )

    # decode one token and compare against forward on the extended sequence
    tok = jnp.argmax(last_full, -1).astype(jnp.int32)[:, None]
    logits_dec, cache = jax.jit(model.decode_step)(
        params, tok, cache, jnp.asarray(s, jnp.int32)
    )
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], tok], 1)
    logits_ext, _ = jax.jit(model.forward)(params, ext)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]),
        np.asarray(logits_ext[:, -1]),
        atol=3e-2,
        rtol=3e-2,
    )


def test_long_500k_applicability_flags():
    """DESIGN.md §Arch-applicability: exactly the sub-quadratic archs run."""
    from repro.launch.shapes import SHAPE_SETS, applicable

    runs = {
        a: applicable(get_arch(a), SHAPE_SETS["long_500k"])[0] for a in ARCHS
    }
    assert runs == {
        "zamba2-2.7b": True,
        "xlstm-350m": True,
        "glm4-9b": False,
        "qwen3-14b": False,
        "stablelm-3b": False,
        "internlm2-1.8b": False,
        "internvl2-26b": False,
        "whisper-tiny": False,
        "qwen2-moe-a2.7b": False,
        "grok-1-314b": False,
    }
