"""RL stack: env semantics, reward accounting, A2C/PPO updates, and the
paper's headline claim — a trained power manager beats always-on energy."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state
from repro.core.rl.a2c import A2CConfig, TrainState, make_batched_sims, make_update_fn
from repro.core.rl.env import EnvConfig, HPCGymEnv, env_reset, env_step
from repro.core.rl.networks import policy_apply, policy_init
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.training.optimizer import adamw
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec

PLAT = PlatformSpec(nb_nodes=16, t_switch_on=120, t_switch_off=180)


def env_cfg(**kw):
    return EnvConfig(
        engine=EngineConfig(
            psm=PSMVariant.RL, base=BasePolicy.EASY, rl_decision_interval=300
        ),
        **kw,
    )


def test_env_requires_rl_psm():
    with pytest.raises(ValueError):
        EnvConfig(engine=EngineConfig(psm=PSMVariant.PSUS))


def test_gym_env_episode_runs_to_done():
    wl = generate_workload(GeneratorConfig(n_jobs=20, nb_res=16, seed=0))
    env = HPCGymEnv(PLAT, wl, env_cfg(max_steps=500))
    obs = env.reset()
    assert obs.shape == (env.observation_size,)
    total_r, steps = 0.0, 0
    done = False
    while not done and steps < 500:
        obs, r, done, info = env.step(steps % env.action_space_n)
        total_r += r
        steps += 1
    assert done
    assert np.isfinite(total_r)
    # all jobs completed by the end of the episode
    d = jax.tree_util.tree_map(np.asarray, env.state.sim)
    assert (d.job_status[d.job_exists] == 3).all()


def test_env_step_noop_after_done():
    wl = generate_workload(GeneratorConfig(n_jobs=3, nb_res=16, seed=1))
    cfg = env_cfg(max_steps=1000)
    const = engine.make_const(PLAT, cfg.engine)
    sim0 = engine.init_state(PLAT, wl, cfg.engine)
    state, obs = env_reset(cfg, const, sim0)
    step = jax.jit(functools.partial(env_step, cfg, const))
    for _ in range(300):
        state, obs, r, done, info = step(state, jnp.asarray(0))
        if bool(done):
            break
    assert bool(done)
    e0 = float(jnp.sum(state.sim.energy))
    state2, _, r2, _, _ = step(state, jnp.asarray(4))
    assert float(jnp.sum(state2.sim.energy)) == e0  # frozen
    assert float(r2) == 0.0


def test_a2c_update_improves_reward_signal():
    """A2C on tiny workloads: update runs, metrics finite, entropy sane."""
    wl = [
        generate_workload(GeneratorConfig(n_jobs=16, nb_res=16, seed=s))
        for s in range(4)
    ]
    cfg = env_cfg(max_steps=64)
    acfg = A2CConfig(n_envs=4, n_steps=8, lr=1e-3)
    const = engine.make_const(PLAT, cfg.engine)
    sims0 = make_batched_sims(PLAT, wl, cfg)
    update, _ = make_update_fn(cfg, const, sims0, acfg)
    params = policy_init(jax.random.PRNGKey(0), cfg.obs_size, cfg.n_actions)
    opt = adamw(lr=acfg.lr)
    env_states, obs = jax.vmap(functools.partial(env_reset, cfg, const))(sims0)
    ts = TrainState(params, opt.init(params), env_states, obs, jax.random.PRNGKey(1))
    update = jax.jit(update)
    for i in range(3):
        ts, m = update(ts)
        assert np.isfinite(float(m["loss"]))
        assert float(m["entropy"]) > 0.0
    # params moved
    delta = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(ts.params)
        )
    )
    assert delta > 0


def test_ppo_update_smoke():
    from repro.core.rl import ppo as P

    wl = [
        generate_workload(GeneratorConfig(n_jobs=12, nb_res=16, seed=s))
        for s in range(4)
    ]
    cfg = env_cfg(max_steps=48)
    pcfg = P.PPOConfig(n_envs=4, n_steps=8, n_epochs=2, n_minibatches=2)
    const = engine.make_const(PLAT, cfg.engine)
    sims0 = make_batched_sims(PLAT, wl, cfg)
    update, opt = P.make_update_fn(cfg, const, sims0, pcfg)
    params = policy_init(jax.random.PRNGKey(0), cfg.obs_size, cfg.n_actions)
    env_states, obs = jax.vmap(functools.partial(env_reset, cfg, const))(sims0)
    ts = TrainState(params, opt.init(params), env_states, obs, jax.random.PRNGKey(1))
    ts, m = jax.jit(update)(ts)
    assert np.isfinite(float(m["loss"]))


def test_rl_all_off_policy_saves_energy_vs_always_on():
    """Sanity: a 'sleep everything idle' RL policy uses less energy than
    always-on on a sparse workload (the paper's motivation)."""
    wl = generate_workload(
        GeneratorConfig(n_jobs=10, nb_res=16, mean_interarrival=4000.0, seed=2)
    )
    # always-on baseline
    s_on = engine.simulate(
        PLAT, wl, EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.NONE)
    )
    m_on = metrics_from_state(s_on, PLAT.power_active)
    # RL env with constant "sleep all idle" action (action 0 of target_fraction)
    cfg = env_cfg(max_steps=2000)
    env = HPCGymEnv(PLAT, wl, cfg)
    env.reset()
    done = False
    steps = 0
    while not done and steps < 2000:
        _, _, done, _ = env.step(0)  # target fraction 0 -> sleep everything
        steps += 1
    m_rl = metrics_from_state(env.state.sim, PLAT.power_active)
    assert m_rl.total_energy_j < 0.7 * m_on.total_energy_j
    # but waiting time worsened (the trade-off the paper studies)
    assert m_rl.mean_wait_s >= m_on.mean_wait_s


def test_feature_extractors_bounded():
    from repro.core.rl.features import FEATURE_EXTRACTORS

    wl = generate_workload(GeneratorConfig(n_jobs=30, nb_res=16, seed=3))
    cfg = env_cfg()
    const = engine.make_const(PLAT, cfg.engine)
    s = engine.init_state(PLAT, wl, cfg.engine)
    s = engine.process_batch(s, const, cfg.engine)
    for name, fn in FEATURE_EXTRACTORS.items():
        feats = fn(s, const) if name != "queue_window" else fn(s, const, 8)
        arr = np.asarray(feats)
        assert np.isfinite(arr).all(), name
        assert (np.abs(arr) <= 16).all(), name


def test_action_translators_within_bounds():
    from repro.core.rl.actions import (
        ACTION_TRANSLATORS,
        action_space_size,
        full_commands,
    )

    wl = generate_workload(GeneratorConfig(n_jobs=10, nb_res=16, seed=4))
    cfg = env_cfg()
    const = engine.make_const(PLAT, cfg.engine)
    s = engine.init_state(PLAT, wl, cfg.engine)
    s = engine.process_batch(s, const, cfg.engine)
    for name, fn in ACTION_TRANSLATORS.items():
        n = action_space_size(name, 9, n_groups=1)
        for a in range(n):
            n_on, n_off, n_mode = full_commands(
                s, fn(s, const, jnp.asarray(a), 9)
            )
            assert n_on.shape == s.rl_on_cmd.shape
            assert n_mode.shape == s.rl_mode_cmd.shape
            assert 0 <= int(n_on.sum()) <= 16
            assert 0 <= int(n_off.sum()) <= 16
            assert int(n_mode.min()) >= -1
