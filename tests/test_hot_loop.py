"""Hot-loop restructure (core/SEMANTICS.md §Hot loop): the fused event pass,
quiet-event batching, the early-exit scheduler scan, and the workload-derived
window trim are all bit-exact with the legacy loop — the fused engine must be
a pure performance change, never a semantic one."""
import dataclasses

import numpy as np
import pytest

from repro.core import engine
from repro.core.policy import RLController, from_label, scheduler_labels
from repro.core.types import EngineConfig
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec, mixed_platform_example
from repro.workloads.workload import workload_from_arrays

SIX = [l for l in scheduler_labels() if "AlwaysOn" not in l]

# every field a schedule/accounting divergence could show up in
EXACT_FIELDS = (
    "t", "job_start", "job_finish", "job_status", "job_eff",
    "job_terminated", "node_state", "node_until", "n_batches", "n_allocs",
    "n_starts", "n_completions", "n_switch_on", "n_switch_off",
    "energy", "energy_c", "wait_integral", "truncated",
)


def _assert_states_equal(a, b, fields=EXACT_FIELDS):
    for fld in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, fld)), np.asarray(getattr(b, fld)),
            err_msg=f"fused/unfused diverged in SimState.{fld}",
        )


@pytest.mark.parametrize("label", SIX)
def test_fused_bit_exact_all_labels(label):
    """Fused loop == legacy loop, bit-for-bit, for all six schedulers."""
    base, pol = from_label(label)
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(
        GeneratorConfig(n_jobs=80, nb_res=16, seed=5, overrun_prob=0.2)
    )
    cfg = EngineConfig(
        base=base, policy=pol, timeout=120, terminate_overrun=True
    )
    fused = engine.simulate(plat, wl, cfg)
    legacy = engine.simulate(
        plat, wl, dataclasses.replace(cfg, fused_events=False)
    )
    _assert_states_equal(fused, legacy)


def test_fused_bit_exact_traced_sweep():
    """The traced superset program (sweep) is fused too — same guarantee."""
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=50, nb_res=16, seed=7))
    cfg = EngineConfig(timeout=90)
    fused = engine.sweep(plat, wl, SIX, cfg)
    legacy = engine.sweep(
        plat, wl, SIX, dataclasses.replace(cfg, fused_events=False)
    )
    _assert_states_equal(fused.states, legacy.states)


def test_fused_bit_exact_heterogeneous():
    """Multi-group platform: the kernel gate stays off (G > 1), the fused-XLA
    path carries per-group ledgers bit-exactly."""
    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=12, seed=2))
    cfg = EngineConfig(timeout=100, node_order="cheap")
    fused = engine.simulate(plat, wl, cfg)
    legacy = engine.simulate(
        plat, wl, dataclasses.replace(cfg, fused_events=False)
    )
    _assert_states_equal(fused, legacy)


def test_quiet_batching_sleep_cycle_trace():
    """A sleep-cycling trace (long gaps, every batch between bursts is pure
    transition/expiry) exercises the quiet path and stays bit-exact."""
    plat = PlatformSpec(nb_nodes=16, t_switch_on=40, t_switch_off=60)
    wl = workload_from_arrays(
        res=[4, 8, 4, 8, 4, 8],
        subtime=[0, 700, 1400, 2100, 2800, 3500],
        runtime=[50, 60, 50, 60, 50, 60],
        nb_res=16,
    )
    cfg = EngineConfig(timeout=10)
    fused = engine.simulate(plat, wl, cfg)
    legacy = engine.simulate(
        plat, wl, dataclasses.replace(cfg, fused_events=False)
    )
    _assert_states_equal(fused, legacy)
    # the trace actually sleep-cycles (so quiet batches were on the path)
    assert int(fused.n_switch_off) >= 8


def test_quiet_gate_is_static():
    """Quiet batching only arms when the skipped rules are statically absent:
    specialized TimeoutSleep yes; RL / traced (sweep) flags no."""
    plat = PlatformSpec(nb_nodes=4)
    cfg = EngineConfig(timeout=60)
    const = engine.make_const(plat, cfg, specialize=True)
    assert engine._quiet_enabled(const, cfg)
    # traced flags (the sweep spelling) keep the full batch
    assert not engine._quiet_enabled(engine.make_const(plat, cfg), cfg)
    cfg_rl = EngineConfig(policy=RLController())
    const_rl = engine.make_const(plat, cfg_rl, specialize=True)
    assert not engine._quiet_enabled(const_rl, cfg_rl)
    # opting out of the fused loop opts out of quiet batching too
    cfg_legacy = dataclasses.replace(cfg, fused_events=False)
    assert not engine._quiet_enabled(
        engine.make_const(plat, cfg_legacy, specialize=True), cfg_legacy
    )


# ------------------------------------------------------------- window trim

def test_window_trim_bit_exact():
    """cfg.window > n_jobs is trimmed (the queue can never fill those slots)
    with bit-exact results vs an explicitly-sized window."""
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=20, nb_res=16, seed=9))
    wide = engine.simulate(plat, wl, EngineConfig(timeout=60, window=64))
    tight = engine.simulate(plat, wl, EngineConfig(timeout=60, window=20))
    _assert_states_equal(wide, tight)


def test_trim_window_bounds():
    cfg = EngineConfig(window=32)
    assert engine.trim_window(cfg, 10).window == 10
    assert engine.trim_window(cfg, 32).window == 32
    # never widened, never below 1
    assert engine.trim_window(cfg, 100).window == 32
    assert engine.trim_window(cfg, 0).window == 1
    # no-op trims return the config unchanged (jit-cache-key identity)
    assert engine.trim_window(cfg, 100) is cfg


def test_window_trim_shares_compiled_program():
    """window=64 and window=48 trim to the same static W for a 20-job
    workload, so simulate() reuses one cached program."""
    plat = PlatformSpec(nb_nodes=8)
    wl = generate_workload(GeneratorConfig(n_jobs=20, nb_res=8, seed=4))
    engine._SIM_FNS.clear()
    engine.simulate(plat, wl, EngineConfig(timeout=60, window=64))
    n_after_first = len(engine._SIM_FNS)
    engine.simulate(plat, wl, EngineConfig(timeout=60, window=48))
    assert len(engine._SIM_FNS) == n_after_first


# ------------------------------------------------------------- kernel path

def test_fused_kernel_path_schedule_exact():
    """Forcing the Pallas kernel route (fused_kernel=True; interpret on CPU)
    keeps the schedule bit-exact — the i32 transition min is exact — and the
    energy equal to rounding (the kernel's per-state sums differ from the
    scatter-add only in f32 reduction order)."""
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=30, nb_res=16, seed=11))
    cfg = EngineConfig(timeout=60)
    kern = engine.simulate(plat, wl, dataclasses.replace(cfg, fused_kernel=True))
    xla = engine.simulate(plat, wl, dataclasses.replace(cfg, fused_kernel=False))
    _assert_states_equal(
        kern, xla,
        fields=(
            "t", "job_start", "job_finish", "job_status", "n_batches",
            "n_allocs", "n_switch_on", "n_switch_off", "truncated",
        ),
    )
    np.testing.assert_allclose(
        np.asarray(kern.energy), np.asarray(xla.energy), rtol=1e-6
    )


def test_fused_flags_are_trace_structure():
    """fused_events / resolved fused_kernel key the jit caches — flipping
    either must not silently reuse a program with the other loop shape."""
    plat = PlatformSpec(nb_nodes=8)
    cfg = EngineConfig(timeout=60)
    key_f = engine._static_trace_key(plat, cfg, 10, 100)
    key_u = engine._static_trace_key(
        plat, dataclasses.replace(cfg, fused_events=False), 10, 100
    )
    key_k = engine._static_trace_key(
        plat, dataclasses.replace(cfg, fused_kernel=True), 10, 100
    )
    assert len({key_f, key_u, key_k}) == 3
