"""Static specialization of single-config runs + the truncation signal
(core/SEMANTICS.md §Static specialization).

Covers: simulate()'s bounded jit cache (repeated same-shape runs compile
once; traced operands like timeout share the entry), specialized-vs-traced
bit-exactness per scheduler label (incl. DVFS stacks) with the oracle as
third witness, the trace-size proof that disabled rules are DCE'd, the
``truncated`` batch-cap flag on both engines (state, metrics, row column,
and the loud warnings in simulate/sweep/experiments/run_sim_gantt), the
exact ledger-based DVFS utilization, and the experiment layer's
single-point fast path.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro import experiments
from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.policy import (
    DVFS,
    PolicyParams,
    from_label,
    scheduler_labels,
    static_bool,
)
from repro.core.ref.pydes import run_pydes
from repro.core.types import EngineConfig
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec, dvfs_platform_example

SIX = tuple(l for l in scheduler_labels() if "AlwaysOn" not in l)


def _wl(n_jobs=60, seed=7, **kw):
    kw.setdefault("overrun_prob", 0.2)
    return generate_workload(
        GeneratorConfig(n_jobs=n_jobs, nb_res=16, seed=seed, **kw)
    )


# ------------------------------------------------------------ flag lowering

def test_policy_params_static_lowering():
    pp = DVFS().params()
    assert pp.static() == PolicyParams(
        backfill=True, eager_ready=True, sleep_enabled=False,
        ipm_enabled=False, rl_enabled=False, rl_grouped=False,
        dvfs_enabled=True, dvfs_rl=False,
        forecast_enabled=False, forecast_dvfs=False,
    )
    assert all(isinstance(v, bool) for v in pp.static())
    # static() round-trips through traced() values
    assert pp.traced().static() == pp.static()
    # the accessor: concrete bools come back as bools, traced flags as None
    assert static_bool(True) is True
    assert static_bool(np.bool_(False)) is False
    assert static_bool(pp.traced().backfill) is None


# ----------------------------------------------------- simulate() jit cache

def test_simulate_compiles_once_for_repeated_calls():
    """Identical shapes + static structure: ONE cached compile, reused
    across calls and across timeout values (timeout is a traced operand)."""
    wl = _wl(n_jobs=20)
    plat = PlatformSpec(nb_nodes=16)
    engine._SIM_FNS.clear()
    cfg = EngineConfig(timeout=120)
    s1, n1 = engine.simulate(plat, wl, cfg, return_compiles=True)
    s2, n2 = engine.simulate(plat, wl, cfg, return_compiles=True)
    assert len(engine._SIM_FNS) == 1
    if n2 is not None:
        assert n1 == n2 == 1, "repeated simulate() recompiled"
    np.testing.assert_array_equal(
        np.asarray(s1.energy), np.asarray(s2.energy)
    )
    # a different timeout is the SAME program (traced operand)
    _, n3 = engine.simulate(
        plat, wl, EngineConfig(timeout=900), return_compiles=True
    )
    assert len(engine._SIM_FNS) == 1
    if n3 is not None:
        assert n3 == 1
    # a different policy point is a different specialized program
    engine.simulate(plat, wl, EngineConfig(policy=DVFS()))
    assert len(engine._SIM_FNS) == 2


def test_sweep_cache_key_includes_controller_dvfs():
    """Two sweeps sharing one in-graph controller but differing in
    RLController.dvfs must NOT share a compiled program: the dvfs flag is
    static trace structure (the controller-arity guard reads it), so the
    legacy-2-tuple guard must still fire on the second sweep."""
    from repro.core.policy import RLController

    def legacy(s, const):  # (on, off) only — invalid under dvfs=True
        return s.rl_on_cmd * 0, s.rl_off_cmd * 0

    plat = dvfs_platform_example(16)
    wl = _wl(n_jobs=5, seed=0)
    engine.sweep(
        plat, wl, ["EASY RL"],
        EngineConfig(policy=RLController(controller=legacy)),
    )
    with pytest.raises(ValueError, match=r"\(on, off, mode\)"):
        engine.sweep(
            plat, wl, ["EASY RL:dvfs"],
            EngineConfig(policy=RLController(dvfs=True, controller=legacy)),
        )


def test_simulate_jit_cache_is_bounded():
    # n_jobs must exceed the window sweep below: trim_window collapses any
    # window > n_jobs onto the same program, which would keep the cache
    # from ever filling.
    wl = _wl(n_jobs=engine._SIM_CACHE_SIZE + 4, seed=0)
    plat = PlatformSpec(nb_nodes=8)
    engine._SIM_FNS.clear()
    for w in range(engine._SIM_CACHE_SIZE + 3):
        engine.simulate(plat, wl, EngineConfig(window=w + 1))
        assert len(engine._SIM_FNS) <= engine._SIM_CACHE_SIZE
    assert len(engine._SIM_FNS) == engine._SIM_CACHE_SIZE


# ----------------------------------------- specialized == traced == oracle

@pytest.mark.parametrize(
    "label",
    SIX + ("EASY DVFS", "EASY PSAS+IPM+DVFS", "EASY RL", "FCFS RL:groups"),
)
def test_specialized_matches_traced_per_label(label):
    """The statically specialized program is bit-exact with the traced
    superset program (and the oracle) for every scheduler label."""
    plat = dvfs_platform_example(16)
    wl = _wl()
    base, pol = from_label(label)
    cfg = EngineConfig(base=base, policy=pol, timeout=240,
                       terminate_overrun=True, node_order="cheap")
    spec = engine.simulate(plat, wl, cfg, specialize=True)
    traced = engine.simulate(plat, wl, cfg, specialize=False)
    np.testing.assert_array_equal(schedule_table(spec), schedule_table(traced))
    np.testing.assert_array_equal(
        np.asarray(spec.energy), np.asarray(traced.energy)
    )
    np.testing.assert_array_equal(
        np.asarray(spec.mode_time), np.asarray(traced.mode_time)
    )
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(spec), des.schedule_table())
    m = metrics_from_state(spec, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


def test_specialized_trace_is_smaller():
    """The point of specialization: disabled rules leave the trace, so the
    specialized program is strictly smaller than the flag-gated superset
    (deterministic DCE proof, no timing)."""
    wl = _wl(n_jobs=10, seed=1)
    plat = PlatformSpec(nb_nodes=16)
    cfg = EngineConfig(timeout=120)  # PSUS: rl/ipm/dvfs rules are all off
    s0 = engine.init_state(plat, wl, cfg)
    c_spec = engine.make_const(plat, cfg, specialize=True)
    c_traced = engine.make_const(plat, cfg)
    n_spec = len(
        jax.make_jaxpr(
            lambda s: engine.process_batch(s, c_spec, cfg)
        )(s0).jaxpr.eqns
    )
    n_traced = len(
        jax.make_jaxpr(
            lambda s: engine.process_batch(s, c_traced, cfg)
        )(s0).jaxpr.eqns
    )
    assert n_spec < n_traced, (n_spec, n_traced)


# ------------------------------------------------------- truncation signal

def test_truncated_flag_engine_and_oracle():
    wl = _wl(n_jobs=40, seed=3)
    plat = PlatformSpec(nb_nodes=16)
    capped = EngineConfig(timeout=120, max_batches=5)
    with pytest.warns(RuntimeWarning, match="PARTIAL"):
        s = engine.simulate(plat, wl, capped)
    assert bool(np.asarray(s.truncated))
    m = metrics_from_state(s, plat)
    assert m.truncated and m.row()["truncated"] is True
    m_ref, des = run_pydes(plat, wl, capped)
    assert des.truncated and m_ref.truncated
    # a finished run is silent: flag off, no row column
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s_ok = engine.simulate(plat, wl, EngineConfig(timeout=120))
    assert not bool(np.asarray(s_ok.truncated))
    m_ok = metrics_from_state(s_ok, plat)
    assert not m_ok.truncated and "truncated" not in m_ok.row()
    m_ref_ok, _ = run_pydes(plat, wl, EngineConfig(timeout=120))
    assert not m_ref_ok.truncated


def test_truncated_sweep_and_gantt_warn():
    wl = _wl(n_jobs=40, seed=3)
    plat = PlatformSpec(nb_nodes=16)
    cfg = EngineConfig(timeout=120, max_batches=5)
    with pytest.warns(RuntimeWarning, match="PARTIAL"):
        batch = engine.sweep(plat, wl, [60, 600], cfg)
    assert all(m.truncated for m in batch.metrics)
    assert all(r["truncated"] for r in batch.rows())
    # run_sim_gantt's log cap raises the same flag on the returned state
    s0 = engine.init_state(plat, wl, cfg)
    const = engine.make_const(plat, cfg, specialize=True)
    s, log = engine.run_sim_gantt(s0, const, cfg, max_batches=5)
    assert bool(np.asarray(s.truncated))
    assert int(log.n) <= 5


# ------------------------------------------------- exact DVFS utilization

def test_dvfs_utilization_uses_the_mode_ledger():
    """Under a non-identity mode table, utilization must come from the
    per-mode energy ledger (exact), not the base active draw — and both
    engines must agree on it."""
    plat = dvfs_platform_example(16)
    wl = _wl(n_jobs=50, seed=4)
    cfg = EngineConfig(policy=DVFS(), node_order="cheap")
    s = engine.simulate(plat, wl, cfg)
    m = metrics_from_state(s, plat)
    m_ref, _ = run_pydes(plat, wl, cfg)
    assert m.utilization == pytest.approx(m_ref.utilization, rel=1e-5)
    # the exact value: sum over [g, m] of mode_energy / mode_watts
    _, watts, _ = plat.group_dvfs_tables()
    me = np.asarray(m.energy_by_mode_j, np.float64)
    active_s = (me / np.where(watts > 0, watts, np.inf)).sum()
    expected = active_s / (plat.nb_nodes * m.makespan_s)
    assert m.utilization == pytest.approx(expected, rel=1e-12)
    # ... and it differs from the old base-draw approximation (the bug)
    eg = np.asarray(m.energy_by_group_j, np.float64)
    naive = sum(
        eg[g, 3] / p for g, p in enumerate(plat.group_active_powers()) if p
    ) / (plat.nb_nodes * m.makespan_s)
    assert m.utilization != pytest.approx(naive, rel=1e-3)
    # identity table (no declared modes): the legacy expression still rules
    plain = PlatformSpec(nb_nodes=16)
    s_id = engine.simulate(plain, wl, EngineConfig(policy=DVFS()))
    m_id = metrics_from_state(s_id, plain)
    m_id_ref, _ = run_pydes(plain, wl, EngineConfig(policy=DVFS()))
    assert m_id.utilization == pytest.approx(m_id_ref.utilization, rel=1e-5)


# ------------------------------------------- experiment-layer fast path

def test_experiment_single_point_takes_the_fast_path():
    """A 1x1 grid routes through the specialized program (compile cached,
    n_compiles == 1) and its row is bit-exact with the sweep program's."""
    exp = experiments.Experiment(
        name="single",
        workload={"preset": "fig3_small", "n_jobs": 30},
        platform=16,
        schedulers=("EASY PSAS",),
        timeouts=(120,),
        terminate_overrun=True,
    )
    result = experiments.run(exp)
    assert len(result.rows) == 1
    if result.n_compiles is not None:
        assert result.n_compiles == 1
    wl = experiments.resolve_workload(exp.workload)
    plat = experiments.resolve_platform(exp.platform)
    batch = engine.sweep(
        plat, wl, [{"scheduler": "EASY PSAS", "timeout": 120}],
        exp.engine_config(),
    )
    row, srow = result.rows[0], batch.rows()[0]
    for k in ("total_energy_kwh", "wasted_energy_kwh", "mean_wait_s",
              "utilization", "makespan_s"):
        assert row[k] == srow[k], k


def test_rl_env_const_is_specialized():
    """The RL rollout path carries concrete policy flags: its closure-bound
    const specializes the trace to the RLController rules."""
    from repro.core.policy import RLController
    from repro.core.rl.env import EnvConfig, HPCGymEnv

    wl = _wl(n_jobs=8, seed=0, overrun_prob=0.0)
    env = HPCGymEnv(
        PlatformSpec(nb_nodes=16), wl,
        EnvConfig(engine=EngineConfig(policy=RLController())),
    )
    assert all(isinstance(v, bool) for v in env.const.policy)
    assert env.const.policy.rl_enabled and not env.const.policy.sleep_enabled
    obs = env.reset()
    assert np.isfinite(np.asarray(obs)).all()
    _, r, _, _ = env.step(0)
    assert np.isfinite(r)
