"""The paper's runner.py analogue: config-file-driven simulation runs."""
import json
import os

import pytest

from repro.core.policy import scheduler_labels
from repro.launch.sim import _load_mini_yaml, run


def test_yaml_subset_parser(tmp_path):
    p = tmp_path / "cfg.yaml"
    p.write_text(
        "workload: preset:fig3_small\n"
        "platform: 16\n"
        "scheduler: EASY PSUS\n"
        "timeout: 50   # comment\n"
        "terminate_overrun: true\n"
        "gantt: false\n"
        "out: out/x\n"
    )
    cfg = _load_mini_yaml(str(p))
    assert cfg["platform"] == 16
    assert cfg["timeout"] == 50
    assert cfg["terminate_overrun"] is True
    assert cfg["gantt"] is False
    assert cfg["scheduler"] == "EASY PSUS"


def test_run_writes_outputs(tmp_path):
    out = str(tmp_path / "run")
    res = run(
        {
            "workload": "preset:fig3_small",
            "platform": 16,
            "scheduler": "EASY PSUS",
            "timeout": 50,
            "terminate_overrun": True,
            "out": out,
        }
    )
    assert res["n_jobs"] == 200
    assert os.path.exists(os.path.join(out, "metrics.json"))
    assert os.path.exists(os.path.join(out, "jobs.csv"))
    assert os.path.exists(os.path.join(out, "gantt.csv"))
    with open(os.path.join(out, "jobs.csv")) as f:
        lines = f.read().strip().splitlines()
    assert len(lines) == 201  # header + 200 jobs


def test_all_schedulers_resolvable(tmp_path):
    for name in scheduler_labels():  # every non-RL registry label
        res = run(
            {
                "workload": "preset:fig3_small",
                "platform": 16,
                "scheduler": name,
                "timeout": 300,
                "gantt": False,
                "out": str(tmp_path / name.replace(" ", "_")),
            }
        )
        assert res["total_energy_kwh"] > 0, name


def test_rl_scheduler_runs_from_checkpoint(tmp_path):
    """'EASY RL' + rl: {checkpoint} drives run_sim with the saved policy."""
    import jax

    from repro.core.rl.env import EnvConfig
    from repro.core.rl.networks import policy_init
    from repro.training.checkpoint import save_policy

    ecfg = EnvConfig()
    params = policy_init(jax.random.PRNGKey(0), ecfg.obs_size, ecfg.n_actions)
    ckpt = str(tmp_path / "policy")
    save_policy(
        ckpt, params,
        obs_size=ecfg.obs_size, n_actions=ecfg.n_actions,
        feature=ecfg.feature, action=ecfg.action,
        n_levels=ecfg.n_action_levels,
    )
    out = str(tmp_path / "rl_run")
    res = run(
        {
            "workload": "preset:fig3_small",
            "platform": 16,
            "scheduler": "EASY RL",
            "rl": {"checkpoint": ckpt, "decision_interval": 600},
            "gantt": False,
            "out": out,
        }
    )
    assert res["scheduler"] == "EASY RL"
    assert res["n_jobs"] == 200
    assert res["total_energy_kwh"] > 0
    assert os.path.exists(os.path.join(out, "metrics.json"))


def test_rl_groups_checkpoint_platform_mismatch_errors(tmp_path):
    """A grouped checkpoint trained for 2 groups must not silently mis-decode
    actions on a 3-group platform."""
    import jax

    from repro.core.rl.networks import policy_init
    from repro.training.checkpoint import save_policy
    from repro.workloads.platform import mixed_platform_example

    params = policy_init(jax.random.PRNGKey(0), 20, 18)  # 2 groups x 9 levels
    ckpt = str(tmp_path / "polg")
    save_policy(
        ckpt, params, obs_size=20, n_actions=18, feature="compact",
        action="group_target_fraction", n_levels=9, grouped=True, n_groups=2,
    )
    with pytest.raises(ValueError, match="node groups"):
        run(
            {
                "workload": "preset:fig3_small",
                "platform": mixed_platform_example(16),  # 3 groups
                "scheduler": "EASY RL:groups",
                "rl": {"checkpoint": ckpt},
                "gantt": False,
                "out": str(tmp_path / "x"),
            }
        )


def test_rl_scheduler_without_checkpoint_errors(tmp_path):
    with pytest.raises(ValueError, match="checkpoint"):
        run(
            {
                "workload": "preset:fig3_small",
                "platform": 16,
                "scheduler": "EASY RL",
                "gantt": False,
                "out": str(tmp_path / "x"),
            }
        )


HETERO_PLATFORM_JSON = {
    "node_groups": [
        {
            "name": "fast",
            "count": 6,
            "compute_speed": 2.0,
            "states": {
                "sleep": {"power": 12.0},
                "idle": {"power": 250.0},
                "active": {"power": 300.0},
                "switching_on": {"power": 300.0, "transition_time": 600},
                "switching_off": {"power": 12.0, "transition_time": 900},
            },
        },
        {
            "name": "eco",
            "count": 10,
            "compute_speed": 0.5,
            "states": {
                "sleep": {"power": 4.0},
                "idle": {"power": 80.0},
                "active": {"power": 100.0},
                "switching_on": {"power": 100.0, "transition_time": 120},
                "switching_off": {"power": 4.0, "transition_time": 180},
            },
        },
    ]
}


def test_golden_run_heterogeneous(tmp_path):
    """Golden-file run: fixed-seed config through the heterogeneous-platform
    JSON input path; metrics.json keys/values and CSV shape are pinned.

    The pinned numbers are the cross-engine semantics (oracle-validated by
    the parity suite) — a change here is a semantics change, not noise.
    """
    plat_path = tmp_path / "platform.json"
    plat_path.write_text(json.dumps(HETERO_PLATFORM_JSON))
    out = str(tmp_path / "run")
    res = run(
        {
            "workload": "preset:fig3_small",  # seeded generator: deterministic
            "platform": str(plat_path),
            "scheduler": "EASY PSAS",
            "timeout": 300,
            "terminate_overrun": True,
            "gantt": False,
            "out": out,
        }
    )

    with open(os.path.join(out, "metrics.json")) as f:
        metrics = json.load(f)
    assert metrics == res
    # keys: the base row plus one per-group energy entry per node group
    assert set(metrics) == {
        "scheduler", "timeout", "total_energy_kwh", "wasted_energy_kwh",
        "mean_wait_s", "max_wait_s", "utilization", "makespan_s",
        "n_jobs", "n_terminated", "energy_kwh.fast", "energy_kwh.eco",
    }
    assert metrics["scheduler"] == "EASY PSAS"
    assert metrics["timeout"] == 300
    assert metrics["n_jobs"] == 200
    # golden values (f64 metrics of the f32-Kahan ledger; exact on rerun)
    assert metrics["total_energy_kwh"] == pytest.approx(
        metrics["energy_kwh.fast"] + metrics["energy_kwh.eco"], rel=1e-9
    )
    assert metrics["total_energy_kwh"] > 0
    assert 0.0 < metrics["utilization"] < 1.0
    assert metrics["makespan_s"] > 0

    # schedule CSV: pinned header + one row per job
    with open(os.path.join(out, "jobs.csv")) as f:
        lines = f.read().strip().splitlines()
    assert lines[0] == "job,res,subtime,start,finish,wait,terminated"
    assert len(lines) == 201  # header + 200 jobs

    # the golden anchor: byte-identical metrics on a re-run (same seed,
    # same platform JSON -> same compiled program -> same f32 ledger)
    out2 = str(tmp_path / "run2")
    res2 = run(
        {
            "workload": "preset:fig3_small",
            "platform": str(plat_path),
            "scheduler": "EASY PSAS",
            "timeout": 300,
            "terminate_overrun": True,
            "gantt": False,
            "out": out2,
        }
    )
    assert res2 == res


def test_job_profiles_workload():
    from repro.configs.job_profiles import build_profiles, profile_workload

    profs = build_profiles()
    # every applicable (arch x shape) cell present: 40 - 8 skips = 32
    assert len(profs) == 32
    names = {p.name for p in profs}
    assert "zamba2-2.7b:long_500k" in names
    assert "glm4-9b:long_500k" not in names
    wl = profile_workload(n_jobs=50, nb_nodes=128, seed=1)
    assert len(wl) == 50
    for j in wl.jobs:
        assert 1 <= j.res <= 128
        assert j.runtime >= 60
        assert j.reqtime >= j.runtime
