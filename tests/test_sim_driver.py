"""The paper's runner.py analogue: config-file-driven simulation runs."""
import json
import os

import pytest

from repro.launch.sim import SCHEDULERS, _load_mini_yaml, run


def test_yaml_subset_parser(tmp_path):
    p = tmp_path / "cfg.yaml"
    p.write_text(
        "workload: preset:fig3_small\n"
        "platform: 16\n"
        "scheduler: EASY PSUS\n"
        "timeout: 50   # comment\n"
        "terminate_overrun: true\n"
        "gantt: false\n"
        "out: out/x\n"
    )
    cfg = _load_mini_yaml(str(p))
    assert cfg["platform"] == 16
    assert cfg["timeout"] == 50
    assert cfg["terminate_overrun"] is True
    assert cfg["gantt"] is False
    assert cfg["scheduler"] == "EASY PSUS"


def test_run_writes_outputs(tmp_path):
    out = str(tmp_path / "run")
    res = run(
        {
            "workload": "preset:fig3_small",
            "platform": 16,
            "scheduler": "EASY PSUS",
            "timeout": 50,
            "terminate_overrun": True,
            "out": out,
        }
    )
    assert res["n_jobs"] == 200
    assert os.path.exists(os.path.join(out, "metrics.json"))
    assert os.path.exists(os.path.join(out, "jobs.csv"))
    assert os.path.exists(os.path.join(out, "gantt.csv"))
    with open(os.path.join(out, "jobs.csv")) as f:
        lines = f.read().strip().splitlines()
    assert len(lines) == 201  # header + 200 jobs


def test_all_schedulers_resolvable(tmp_path):
    for name in SCHEDULERS:
        res = run(
            {
                "workload": "preset:fig3_small",
                "platform": 16,
                "scheduler": name,
                "timeout": 300,
                "gantt": False,
                "out": str(tmp_path / name.replace(" ", "_")),
            }
        )
        assert res["total_energy_kwh"] > 0, name


def test_job_profiles_workload():
    from repro.configs.job_profiles import build_profiles, profile_workload

    profs = build_profiles()
    # every applicable (arch x shape) cell present: 40 - 8 skips = 32
    assert len(profs) == 32
    names = {p.name for p in profs}
    assert "zamba2-2.7b:long_500k" in names
    assert "glm4-9b:long_500k" not in names
    wl = profile_workload(n_jobs=50, nb_nodes=128, seed=1)
    assert len(wl) == 50
    for j in wl.jobs:
        assert 1 <= j.res <= 128
        assert j.runtime >= 60
        assert j.reqtime >= j.runtime
