"""SWF trace replay (workloads/traces.py): the streaming reader must be a
drop-in parse_swf twin on real-archive warts (both go through the one
shared cleaning rule), and the replay adaptations (rebase, proc→node
mapping, oversize policies) must compose into engine-ready workloads."""
import numpy as np
import pytest

from repro.core import engine
from repro.core.types import EngineConfig
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec
from repro.workloads.traces import (
    OVERSIZE_POLICIES,
    iter_swf_chunks,
    map_procs_to_nodes,
    read_swf,
    rebase_submit_times,
    replay_workload,
    synthesize_curie_swf,
    write_swf,
)
from repro.workloads.workload import Workload, parse_swf, workload_from_arrays


def _ragged_swf(path: str, n: int = 2_000) -> None:
    """The PR 6 warts fixture, scaled down: comment headers, blank lines,
    ragged short lines, descending job ids, unsorted subtimes, unknown
    runtimes, zero-proc rows, missing reqtimes."""
    lines = [
        "; SWF trace (synthetic)",
        "; MaxProcs: 320",
        "",
    ]

    def h(i, k):
        return (i * 2654435761 + k * 40503) % 2**16

    for i in range(n):
        jid = n - i
        subtime = h(i, 1) % 50_000
        kind = i % 100
        if kind == 0:
            lines.append(f"{jid} {subtime} 0 17")  # ragged, skip
            continue
        if kind == 1:
            lines.append("")
            continue
        runtime = -1 if kind == 2 else 1 + h(i, 2) % 3600
        procs = 0 if kind == 3 else 1 + h(i, 3) % 320
        reqtime = -1 if kind == 4 else runtime + h(i, 4) % 600
        lines.append(
            f"{jid} {subtime} 10 {runtime} {procs} -1 -1 {procs} {reqtime}"
            " -1 1 1 1 1 1 1 -1 -1"
        )
    with open(path, "w") as f:
        f.write("\n".join(lines))


# ------------------------------------------------------------ reader parity

@pytest.mark.parametrize("chunk_jobs", [7, 512, 100_000])
def test_read_swf_matches_parse_swf(tmp_path, chunk_jobs):
    """The streaming reader == the one-shot parser on the ragged fixture,
    for chunk sizes below, at, and above the trace length."""
    path = str(tmp_path / "warts.swf")
    _ragged_swf(path)
    ref = parse_swf(path)
    got = read_swf(path, chunk_jobs=chunk_jobs)
    assert got.nb_res == ref.nb_res == 320
    assert got.jobs == ref.jobs


def test_read_swf_max_jobs_prefix(tmp_path):
    """max_jobs keeps the first K *kept* records (cleaning applied), not
    the first K lines — and they are a subset of the full parse."""
    path = str(tmp_path / "warts.swf")
    _ragged_swf(path)
    got = read_swf(path, max_jobs=100)
    assert len(got) == 100
    assert set(got.jobs) <= set(parse_swf(path).jobs)


def test_iter_swf_chunks_shapes(tmp_path):
    path = str(tmp_path / "warts.swf")
    _ragged_swf(path, n=500)
    chunks = list(iter_swf_chunks(path, chunk_jobs=64))
    # MaxProcs rides on the FIRST chunk only (streaming cannot wait for EOF)
    assert chunks[0]["max_procs"] == 320
    assert all("max_procs" not in c for c in chunks[1:])
    sizes = [len(c["job_id"]) for c in chunks]
    assert all(s == 64 for s in sizes[:-1]) and 0 < sizes[-1] <= 64
    assert sum(sizes) == len(parse_swf(path))
    for c in chunks:
        for k in ("job_id", "res", "subtime", "reqtime", "runtime"):
            assert c[k].dtype == np.int64


def test_iter_swf_chunks_empty_trace(tmp_path):
    """A header-only trace still yields one (empty) chunk with MaxProcs."""
    path = str(tmp_path / "empty.swf")
    with open(path, "w") as f:
        f.write("; MaxProcs: 64\n")
    chunks = list(iter_swf_chunks(path))
    assert len(chunks) == 1
    assert chunks[0]["max_procs"] == 64
    assert len(chunks[0]["job_id"]) == 0
    with pytest.raises(ValueError, match="chunk_jobs"):
        list(iter_swf_chunks(path, chunk_jobs=0))


# ------------------------------------------------------------- adaptations

def test_rebase_submit_times():
    wl = workload_from_arrays(
        np.asarray([1, 1, 1], np.int64),
        np.asarray([1000, 1000, 1500], np.int64),
        np.asarray([10, 10, 10], np.int64),
        nb_res=4,
    )
    out = rebase_submit_times(wl)
    assert [j.subtime for j in out.jobs] == [0, 0, 500]
    # already-rebased workloads pass through untouched
    assert rebase_submit_times(out) is out


def test_map_procs_to_nodes_policies():
    wl = workload_from_arrays(
        np.asarray([3, 8, 20], np.int64),
        np.asarray([0, 0, 0], np.int64),
        np.asarray([10, 10, 10], np.int64),
        nb_res=32,
    )
    # ceil(procs / procs_per_node), nb_res becomes the node count
    out = map_procs_to_nodes(wl, nb_nodes=10, procs_per_node=2)
    assert out.nb_res == 10
    assert [j.res for j in out.jobs] == [2, 4, 10]

    clamped = map_procs_to_nodes(wl, nb_nodes=10, oversize="clamp")
    assert [j.res for j in clamped.jobs] == [3, 8, 10]
    dropped = map_procs_to_nodes(wl, nb_nodes=10, oversize="drop")
    assert [j.res for j in dropped.jobs] == [3, 8]
    with pytest.raises(ValueError, match="oversize='clamp' or 'drop'"):
        map_procs_to_nodes(wl, nb_nodes=10, oversize="error")
    with pytest.raises(ValueError, match="oversize must be one of"):
        map_procs_to_nodes(wl, nb_nodes=10, oversize="truncate")
    assert OVERSIZE_POLICIES == ("clamp", "drop", "error")


def test_write_swf_round_trip(tmp_path):
    """write_swf → read_swf is the identity on the modeled fields."""
    wl = generate_workload(GeneratorConfig(n_jobs=200, nb_res=64, seed=13))
    path = str(tmp_path / "rt.swf")
    write_swf(wl, path)
    back = read_swf(path)
    assert back.nb_res == wl.nb_res
    want = wl.sorted_by_subtime()
    assert len(back) == len(want)
    for a, b in zip(back.jobs, want.jobs):
        assert (a.job_id, a.res, a.subtime, a.runtime, a.reqtime) == (
            b.job_id, b.res, b.subtime, b.runtime, b.reqtime
        )


def test_replay_workload_end_to_end(tmp_path):
    """parse → map → rebase composition on the ragged fixture, simulated
    to completion on a small platform (the oversize clamp is exercised —
    the fixture has jobs up to 320 procs)."""
    path = str(tmp_path / "warts.swf")
    _ragged_swf(path, n=500)
    wl = replay_workload(path, nb_nodes=16, oversize="clamp", max_jobs=40)
    assert wl.nb_res == 16
    assert min(j.subtime for j in wl.jobs) == 0
    assert max(j.res for j in wl.jobs) <= 16
    subs = [j.subtime for j in wl.jobs]
    assert subs == sorted(subs)

    s = engine.simulate(PlatformSpec(nb_nodes=16), wl, EngineConfig(timeout=60))
    assert int(np.asarray(s.n_completions)) == len(wl)


def test_replay_workload_platform_from_header(tmp_path):
    """nb_nodes=None sizes the platform from MaxProcs / procs_per_node."""
    path = str(tmp_path / "warts.swf")
    _ragged_swf(path, n=300)
    wl = replay_workload(path, procs_per_node=4)
    assert wl.nb_res == 80  # ceil(320 / 4)


def test_synthesize_curie_swf_deterministic(tmp_path):
    p1 = synthesize_curie_swf(str(tmp_path / "a.swf"), n_jobs=50)
    p2 = synthesize_curie_swf(str(tmp_path / "b.swf"), n_jobs=50)
    with open(p1) as f1, open(p2) as f2:
        assert f1.read() == f2.read()
    wl = replay_workload(p1, nb_nodes=11_200)
    assert len(wl) == 50 and wl.nb_res == 11_200


# --------------------------------------------------------- experiment specs

def test_resolve_workload_swf_specs(tmp_path):
    from repro.experiments.spec import resolve_workload

    path = str(tmp_path / "warts.swf")
    _ragged_swf(path, n=300)
    a = resolve_workload(f"swf:{path}")
    assert a.nb_res == 320
    b = resolve_workload(
        {"swf": path, "nb_nodes": 16, "max_jobs": 20, "oversize": "clamp"}
    )
    assert b.nb_res == 16 and len(b) == 20
    # replay is not seeded: the replicate axis must refuse
    with pytest.raises(ValueError, match="replications"):
        resolve_workload(f"swf:{path}", replication=1)
    with pytest.raises(ValueError, match="replications"):
        resolve_workload({"swf": path}, replication=2)
    with pytest.raises(ValueError, match="unknown swf workload spec key"):
        resolve_workload({"swf": path, "nb_node": 16})


def test_experiment_swf_spec_runs(tmp_path):
    """A declarative swf experiment round-trips through JSON and runs the
    grid (grouped tables on) end to end."""
    from repro import experiments

    path = str(tmp_path / "warts.swf")
    _ragged_swf(path, n=300)
    exp = experiments.Experiment(
        name="swf_replay",
        workload={"swf": path, "nb_nodes": 16, "max_jobs": 30},
        platform=16,
        schedulers=("EASY PSUS",),
        timeouts=(60,),
        grouped_tables=True,
    )
    exp2 = experiments.Experiment.from_json(exp.to_json())
    assert exp2 == exp
    result = experiments.run(exp)
    assert len(result.rows) == 1
    assert result.rows[0]["n_jobs"] == 30
    with pytest.raises(ValueError, match="unknown swf workload spec key"):
        experiments.Experiment(
            name="typo", workload={"swf": path, "overside": "clamp"},
            platform=16,
        )
