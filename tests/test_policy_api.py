"""Composable power-policy API: the PSMVariant deprecation shim, the
from_label registry, oracle parity for every registered policy stack
(including group-targeted RL actions), the idle-watts node order, and the
engine.sweep one-compile batched driver."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state, np_state, schedule_table
from repro.core.policy import (
    IPM,
    AlwaysOn,
    RLController,
    TimeoutSleep,
    from_label,
    label_of,
    policy_from_psm,
    psm_of,
    scheduler_labels,
)
from repro.core.ref.pydes import run_pydes
from repro.core.types import (
    IDLE,
    SLEEP,
    WAITING,
    BasePolicy,
    EngineConfig,
    PSMVariant,
)
from repro.workloads.generator import PRESETS, GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec, mixed_platform_example

I32 = jnp.int32

ALL_PAIRS = [
    (base, psm)
    for base in (BasePolicy.FCFS, BasePolicy.EASY)
    for psm in PSMVariant
]


# ------------------------------------------------------------- shim mapping

def test_psm_shim_maps_to_equivalent_policy_configs():
    """Every legacy (BasePolicy, PSMVariant) pair builds the identical
    EngineConfig as the explicit policy spelling — same hash, same label."""
    expected = {
        PSMVariant.NONE: AlwaysOn(),
        PSMVariant.PSUS: TimeoutSleep(),
        PSMVariant.PSAS: TimeoutSleep(transition_aware=True),
        PSMVariant.PSAS_IPM: IPM(),
        PSMVariant.RL: RLController(),
    }
    for base, psm in ALL_PAIRS:
        pol = expected[psm]
        assert policy_from_psm(psm) == pol
        assert psm_of(pol) == psm
        legacy = EngineConfig(base=base, psm=psm, timeout=300)
        modern = EngineConfig(base=base, policy=pol, timeout=300)
        assert legacy == modern
        assert hash(legacy) == hash(modern)
        assert legacy.policy == pol
        assert legacy.label() == modern.label()


def test_policy_takes_precedence_over_psm():
    """When both are given, policy wins and psm is re-mirrored from it —
    required so dataclasses.replace(cfg, policy=...) works on configs whose
    psm was auto-mirrored."""
    cfg = EngineConfig(psm=PSMVariant.PSUS, policy=IPM())
    assert cfg.policy == IPM()
    assert cfg.psm == PSMVariant.PSAS_IPM
    swapped = dataclasses.replace(EngineConfig(timeout=60), policy=IPM())
    assert swapped.policy == IPM()
    assert swapped.psm == PSMVariant.PSAS_IPM
    assert swapped == EngineConfig(policy=IPM(), timeout=60)


def test_default_config_is_psus():
    cfg = EngineConfig()
    assert cfg.policy == TimeoutSleep()
    assert cfg.psm == PSMVariant.PSUS


def test_replace_preserves_policy():
    cfg = EngineConfig(policy=RLController(grouped=True), timeout=60)
    cfg2 = dataclasses.replace(cfg, timeout=120)
    assert cfg2.policy == RLController(grouped=True)


# ------------------------------------------------------------- label registry

def test_from_label_registry_roundtrip():
    for label in scheduler_labels(include_rl=True):
        base, pol = from_label(label)
        assert label_of(base, pol) == label
    # aliases and case-insensitivity
    assert from_label("EASY PSAS(AutoOn)") == from_label("easy psas")
    assert from_label("FCFS RL:groups")[1] == RLController(grouped=True)
    with pytest.raises(KeyError, match="unknown scheduler label"):
        from_label("EASY PSASx")


def test_label_matches_legacy_scheduler_table():
    """The labels launch/sim historically accepted resolve to the same
    (base, psm) pairs the old SCHEDULERS dict hardcoded."""
    legacy = {
        "FCFS PSUS": (BasePolicy.FCFS, PSMVariant.PSUS),
        "EASY PSUS": (BasePolicy.EASY, PSMVariant.PSUS),
        "FCFS PSAS": (BasePolicy.FCFS, PSMVariant.PSAS),
        "EASY PSAS": (BasePolicy.EASY, PSMVariant.PSAS),
        "FCFS PSAS+IPM": (BasePolicy.FCFS, PSMVariant.PSAS_IPM),
        "EASY PSAS+IPM": (BasePolicy.EASY, PSMVariant.PSAS_IPM),
        "EASY AlwaysOn": (BasePolicy.EASY, PSMVariant.NONE),
        "FCFS AlwaysOn": (BasePolicy.FCFS, PSMVariant.NONE),
    }
    for label, (base, psm) in legacy.items():
        b, pol = from_label(label)
        assert b == base and psm_of(pol) == psm, label


# ----------------------------------------------- shim bit-exactness (seed)

def _fig3():
    return generate_workload(PRESETS["fig3_small"])


def _assert_states_identical(s1, s2):
    for k, a in np_state(s1).items():
        np.testing.assert_array_equal(a, np.asarray(getattr(s2, k)), err_msg=k)


@pytest.mark.parametrize(
    "base,psm",
    [
        (BasePolicy.EASY, PSMVariant.PSUS),
        (BasePolicy.FCFS, PSMVariant.PSAS),
        (BasePolicy.EASY, PSMVariant.PSAS_IPM),
        (BasePolicy.EASY, PSMVariant.NONE),
        (BasePolicy.EASY, PSMVariant.RL),
    ],
)
def test_shim_bit_identical_on_fig3_small(base, psm):
    """Legacy psm spelling and explicit policy spelling produce bit-identical
    run_sim output on the fig3_small preset."""
    wl = _fig3()
    plat = PlatformSpec(nb_nodes=16)
    s_legacy = engine.simulate(
        plat, wl, EngineConfig(base=base, psm=psm, timeout=300,
                               terminate_overrun=True)
    )
    s_modern = engine.simulate(
        plat, wl, EngineConfig(base=base, policy=policy_from_psm(psm),
                               timeout=300, terminate_overrun=True)
    )
    _assert_states_identical(s_legacy, s_modern)


@pytest.mark.slow
@pytest.mark.parametrize("base,psm", ALL_PAIRS)
def test_shim_bit_identical_full_matrix(base, psm):
    """Widened coverage of test_shim_bit_identical_on_fig3_small: every
    legacy (BasePolicy, PSMVariant) pair."""
    test_shim_bit_identical_on_fig3_small(base, psm)


# --------------------------------------------- oracle parity per label

@pytest.mark.parametrize("label", [l for l in scheduler_labels()])
def test_label_stack_oracle_parity(label):
    """Every non-RL policy stack reachable from from_label: bit-exact
    schedules + energy agreement vs the sequential oracle, on a 3-group
    heterogeneous platform."""
    base, pol = from_label(label)
    plat = mixed_platform_example(16)
    wl = generate_workload(
        GeneratorConfig(n_jobs=60, nb_res=16, seed=11, overrun_prob=0.2)
    )
    cfg = EngineConfig(base=base, policy=pol, timeout=240,
                       terminate_overrun=True, node_order="cheap")
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    assert m.makespan_s == m_ref.makespan_s


def _scripted_controllers():
    """Deterministic scripted RL policy implemented identically for both
    engines: wake every sleeping node (per group) while demand is queued,
    sleep every unreserved idle node when the queue is empty."""

    def jax_ctrl(s, const):
        G = s.rl_on_cmd.shape[0]
        waiting = (s.job_status == WAITING) & (s.job_subtime <= s.t)
        demand = jnp.sum(jnp.where(waiting, s.job_res, 0))
        unres = s.node_job < 0
        sleeping = jnp.zeros(G, I32).at[const.group_id].add(
            (unres & (s.node_state == SLEEP)).astype(I32)
        )
        idle = jnp.zeros(G, I32).at[const.group_id].add(
            (unres & (s.node_state == IDLE)).astype(I32)
        )
        on = jnp.where(demand > 0, sleeping, 0)
        off = jnp.where(demand == 0, idle, 0)
        return on, off

    def py_ctrl(des):
        G = des.n_groups
        demand = des._queued_demand()
        sleeping = np.zeros(G, int)
        idle = np.zeros(G, int)
        for nd in des.nodes:
            if nd.job < 0 and nd.state == SLEEP:
                sleeping[des.gid[nd.nid]] += 1
            if nd.job < 0 and nd.state == IDLE:
                idle[des.gid[nd.nid]] += 1
        on = sleeping if demand > 0 else np.zeros(G, int)
        off = idle if demand == 0 else np.zeros(G, int)
        return on, off

    return jax_ctrl, py_ctrl


@pytest.mark.parametrize("grouped", [False, True])
def test_rl_controller_oracle_parity(grouped):
    """RL policy stacks (global and per-group command modes): an in-graph
    scripted controller driving run_sim matches the oracle's rl_policy
    bit-exactly on a heterogeneous platform."""
    jax_ctrl, py_ctrl = _scripted_controllers()
    plat = mixed_platform_example(16)
    wl = generate_workload(GeneratorConfig(n_jobs=50, nb_res=16, seed=5))
    cfg = EngineConfig(
        base=BasePolicy.EASY,
        policy=RLController(grouped=grouped, controller=jax_ctrl),
        rl_decision_interval=600,
        node_order="cheap",
    )
    s = engine.simulate(plat, wl, cfg)
    cfg_ref = EngineConfig(
        base=BasePolicy.EASY, policy=RLController(grouped=grouped),
        rl_decision_interval=600, node_order="cheap",
    )
    m_ref, des = run_pydes(plat, wl, cfg_ref, rl_policy=py_ctrl)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


def test_grouped_commands_target_their_group():
    """A grouped off-command for group 1 must never sleep group-0 nodes
    (the global mode would)."""
    from repro.core.policy import apply_rl_commands

    plat = mixed_platform_example(16)  # groups: fast[0:5], eco[5:10], std
    wl = generate_workload(GeneratorConfig(n_jobs=4, nb_res=16, seed=0))
    cfg = EngineConfig(policy=RLController(grouped=True))
    s = engine.init_state(plat, wl, cfg)
    const = engine.make_const(plat, cfg)
    off = jnp.zeros(3, I32).at[1].set(3)
    s2 = apply_rl_commands(
        s._replace(rl_off_cmd=off), const, grouped=True
    )
    st = np.asarray(s2.node_state)
    assert (st[:5] == IDLE).all()  # fast group untouched
    assert (st[5:8] != IDLE).any()  # eco group received the command


# ------------------------------------------------------------- idle-watts

def test_idle_watts_order_validated():
    with pytest.raises(ValueError, match="node_order"):
        EngineConfig(node_order="cheapest")
    EngineConfig(node_order="idle-watts")  # accepted


def test_idle_watts_prefers_low_idle_draw_nodes():
    """MIXED platform idle watts: eco 80 < std 190 < fast 250, while the
    'cheap' key prefers fast first — a 1-node job lands on an eco node
    (speed 0.5 -> realized runtime doubles) under idle-watts."""
    from repro.workloads.workload import workload_from_arrays

    plat = mixed_platform_example(16)
    wl = workload_from_arrays(
        res=[1], subtime=[0], runtime=[100], reqtime=[400], nb_res=16
    )
    base = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS,
                        timeout=3600)
    t_cheap = schedule_table(
        engine.simulate(plat, wl, dataclasses.replace(base, node_order="cheap"))
    )[0, 1]
    t_idle = schedule_table(
        engine.simulate(
            plat, wl, dataclasses.replace(base, node_order="idle-watts")
        )
    )[0, 1]
    assert t_cheap == 50.0  # fast node, speed 2.0
    assert t_idle == 200.0  # eco node, speed 0.5


@pytest.mark.parametrize(
    "base,psm",
    [(BasePolicy.EASY, PSMVariant.PSAS), (BasePolicy.FCFS, PSMVariant.PSUS),
     (BasePolicy.EASY, PSMVariant.PSAS_IPM)],
)
def test_idle_watts_oracle_parity(base, psm):
    """idle-watts ordering: exact schedule parity vs the oracle on a
    heterogeneous platform."""
    plat = mixed_platform_example(16)
    wl = generate_workload(
        GeneratorConfig(n_jobs=70, nb_res=16, seed=4, overrun_prob=0.2)
    )
    cfg = EngineConfig(base=base, psm=psm, timeout=200,
                       terminate_overrun=True, node_order="idle-watts")
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


# ------------------------------------------------------------- sweep driver

def test_sweep_matches_individual_simulate():
    """8 timeout/platform scenarios in ONE compiled program: per-scenario
    metrics equal individual simulate() runs; exactly one compilation."""
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=50, nb_res=16, seed=2))
    # window=24 gives this test its own jit cache entry (the compile-count
    # assertion must not see other tests' sweeps)
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS,
                       timeout=300, window=24)
    timeouts = [60, 300, 900, 1800, 2400, 3600]
    hot_plat = PlatformSpec(nb_nodes=16, power_idle=250.0)
    scenarios = timeouts + [None, hot_plat]
    batch = engine.sweep(plat, wl, scenarios, cfg)
    assert len(batch) == 8
    if batch.n_compiles is not None:
        assert batch.n_compiles == 1
    # a second identical-shape sweep reuses the compiled program
    batch2 = engine.sweep(plat, wl, scenarios, cfg)
    if batch2.n_compiles is not None:
        assert batch2.n_compiles == 1

    for i, t in enumerate(timeouts + [None]):
        single = engine.simulate(
            plat, wl, dataclasses.replace(cfg, timeout=t)
        )
        m1 = metrics_from_state(single, plat)
        assert batch[i].makespan_s == m1.makespan_s
        assert batch[i].mean_wait_s == m1.mean_wait_s
        np.testing.assert_allclose(
            batch[i].total_energy_j, m1.total_energy_j, rtol=1e-6
        )
    # the platform scenario: the hot idle draw was a traced operand
    m_hot = metrics_from_state(
        engine.simulate(hot_plat, wl, cfg), hot_plat
    )
    np.testing.assert_allclose(
        batch[7].total_energy_j, m_hot.total_energy_j, rtol=1e-6
    )
    assert batch[7].total_energy_j > batch[1].total_energy_j


def test_sweep_rejects_mismatched_platform_and_empty_axis():
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=10, nb_res=16, seed=0))
    cfg = EngineConfig(timeout=300)
    with pytest.raises(ValueError, match="share node count"):
        engine.sweep(plat, wl, [PlatformSpec(nb_nodes=8)], cfg)
    with pytest.raises(ValueError, match="at least one scenario"):
        engine.sweep(plat, wl, [], cfg)
    with pytest.raises(TypeError, match="unsupported sweep scenario"):
        engine.sweep(plat, wl, [object()], cfg)
    with pytest.raises(TypeError, match="unknown sweep scenario key"):
        engine.sweep(plat, wl, [{"timeot": 60}], cfg)
    with pytest.raises(ValueError, match="controller"):
        # in-graph controllers are static trace structure, not an axis point
        engine.sweep(
            plat, wl, [RLController(controller=lambda s, c: (0, 0))], cfg
        )


def test_sweep_timeouts_need_no_placeholder_config_timeout():
    """Pre-traced-axis engines compiled the timeout-expiry event candidate
    only when config.timeout was set, so sweeping timeouts under
    config.timeout=None was a guarded error. The superset program always
    carries the (flag-gated) candidate: the sweep now simply works and
    matches per-config runs."""
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=30, nb_res=16, seed=6))
    batch = engine.sweep(plat, wl, [60, 900], EngineConfig())
    for i, t in enumerate([60, 900]):
        single = engine.simulate(plat, wl, EngineConfig(timeout=t))
        m1 = metrics_from_state(single, plat)
        assert batch[i].makespan_s == m1.makespan_s
        np.testing.assert_allclose(
            batch[i].total_energy_j, m1.total_energy_j, rtol=1e-6
        )


# ------------------------------------------------- grouped RL env plumbing

def test_grouped_env_config_validation():
    from repro.core.rl.env import EnvConfig

    with pytest.raises(ValueError, match="grouped"):
        EnvConfig(action="group_target_fraction")  # policy not grouped
    with pytest.raises(ValueError, match="grouped"):
        EnvConfig(engine=EngineConfig(policy=RLController(grouped=True)))
    cfg = EnvConfig(
        engine=EngineConfig(policy=RLController(grouped=True)),
        action="group_target_fraction",
        feature="compact_groups",
        n_groups=3,
    )
    assert cfg.n_actions == 3 * 9
    assert cfg.obs_size == 20 + 6 * 3


def test_grouped_env_episode_runs():
    from repro.core.rl.env import EnvConfig, HPCGymEnv

    plat = mixed_platform_example(16)
    wl = generate_workload(GeneratorConfig(n_jobs=12, nb_res=16, seed=1))
    cfg = EnvConfig(
        engine=EngineConfig(
            policy=RLController(grouped=True),
            base=BasePolicy.EASY,
            rl_decision_interval=300,
        ),
        action="group_target_fraction",
        feature="compact_groups",
        n_groups=3,
        max_steps=400,
    )
    env = HPCGymEnv(plat, wl, cfg)
    obs = env.reset()
    assert obs.shape == (cfg.obs_size,)
    done, steps = False, 0
    while not done and steps < 400:
        obs, r, done, _ = env.step(steps % cfg.n_actions)
        assert np.isfinite(r)
        steps += 1
    assert done
    d = jax.tree_util.tree_map(np.asarray, env.state.sim)
    assert (d.job_status[d.job_exists] == 3).all()


def test_grouped_env_n_groups_mismatch_rejected():
    from repro.core.rl.env import EnvConfig, HPCGymEnv

    plat = mixed_platform_example(16)  # 3 groups
    wl = generate_workload(GeneratorConfig(n_jobs=5, nb_res=16, seed=0))
    cfg = EnvConfig(
        engine=EngineConfig(policy=RLController(grouped=True)),
        action="group_target_fraction",
        n_groups=2,
    )
    with pytest.raises(ValueError, match="node groups"):
        HPCGymEnv(plat, wl, cfg)
