"""Device-sharded sweeps, streaming experiment runs, partition-aware
allocation, and the simulation service (core/SEMANTICS.md §Device-sharded
sweeps, §Partition-aware allocation).

Multi-device lanes run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (conftest's
``run_subprocess``), so the main pytest process keeps its 1-device view.
"""
import dataclasses
import textwrap

import numpy as np
import pytest

from conftest import run_subprocess
from repro import experiments
from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec, mixed_platform_example


# ------------------------------------------------------- device resolution

def test_resolve_devices_validation():
    cfg = EngineConfig()
    assert engine._resolve_devices(None, cfg) is None
    assert engine._resolve_devices("all", cfg) >= 1
    assert engine._resolve_devices(1, cfg) == 1
    # None falls back to config.devices
    assert engine._resolve_devices(None, dataclasses.replace(cfg, devices=1)) == 1
    with pytest.raises(ValueError, match="devices must be >= 1"):
        engine._resolve_devices(0, cfg)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        engine._resolve_devices(1_000_000, cfg)


def test_config_devices_validation():
    EngineConfig(devices=1)
    EngineConfig(devices="all")
    with pytest.raises(ValueError):
        EngineConfig(devices=0)
    with pytest.raises(ValueError):
        EngineConfig(devices="half")


def test_sweep_devices_one_matches_unsharded():
    """The D=1 mesh path (shard_map over one device) is bit-exact with the
    legacy unsharded jit(vmap) dispatch."""
    plat = PlatformSpec(nb_nodes=16)
    wl = generate_workload(GeneratorConfig(n_jobs=30, nb_res=16, seed=0))
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS)
    scenarios = [60, 300, None]
    ref = engine.sweep(plat, wl, scenarios, cfg)
    sh = engine.sweep(plat, wl, scenarios, cfg, devices=1)
    assert sh.devices == 1 and ref.devices is None
    for a, b in zip(
        np.asarray(ref.states.energy), np.asarray(sh.states.energy)
    ):
        np.testing.assert_array_equal(a, b)
    for ma, mb in zip(ref.metrics, sh.metrics):
        assert ma.total_energy_j == mb.total_energy_j
        assert ma.makespan_s == mb.makespan_s


def test_sweep_cache_stats_tick_and_key_separation():
    """Hit/miss accounting (the service layer's reuse ledger) and the
    cache-key rule: sharded and unsharded programs of the same grid never
    share an entry."""
    plat = PlatformSpec(nb_nodes=8)
    wl = generate_workload(GeneratorConfig(n_jobs=12, nb_res=8, seed=4))
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS)
    scenarios = [60, 600]

    s0 = engine.cache_stats()
    first = engine.sweep(plat, wl, scenarios, cfg)
    s1 = engine.cache_stats()
    again = engine.sweep(plat, wl, scenarios, cfg)
    s2 = engine.cache_stats()
    sharded = engine.sweep(plat, wl, scenarios, cfg, devices=1)
    s3 = engine.cache_stats()

    assert s1["sweep_misses"] == s0["sweep_misses"] + 1
    assert not first.cache_hit
    assert s2 == {**s1, "sweep_hits": s1["sweep_hits"] + 1}
    assert again.cache_hit
    # same grid, devices=1: a different program (new miss), not a reuse
    assert s3["sweep_misses"] == s2["sweep_misses"] + 1
    assert not sharded.cache_hit


def test_sweep_async_overlap_handle():
    """sweep_async returns before result(); result() is idempotent and
    equals the blocking sweep."""
    plat = PlatformSpec(nb_nodes=8)
    wl = generate_workload(GeneratorConfig(n_jobs=12, nb_res=8, seed=4))
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS)
    pending = engine.sweep_async(plat, wl, [60, 600], cfg)
    batch = pending.result()
    assert pending.result() is batch
    ref = engine.sweep(plat, wl, [60, 600], cfg)
    for ma, mb in zip(batch.metrics, ref.metrics):
        assert ma.total_energy_j == mb.total_energy_j


# ------------------------------------------------------- streaming runner

def _stream_spec(out):
    return experiments.Experiment(
        name="stream",
        workload={"preset": "fig3_small", "n_jobs": 30},
        platform=16,
        schedulers=("EASY PSUS", "FCFS PSAS"),
        timeouts=(60, 600),
        out=out,
    )


def test_streaming_matches_blocking_bytes(tmp_path):
    """stream=True yields chunk-by-chunk; rows AND the on-disk
    metrics.json / rows.csv bytes equal the blocking path's."""
    out = tmp_path / "out"
    exp = _stream_spec(str(out))
    blocking = experiments.run(exp)
    golden = {
        p: (out / p).read_bytes() for p in ("metrics.json", "rows.csv")
    }

    sr = experiments.run(exp, stream=True, chunk_scenarios=3)
    chunks = list(sr)
    assert sr.result is not None
    # 4 scenarios in chunks of <=3 -> two chunks, grid order preserved
    assert [len(c) for c in chunks] == [3, 1]
    flat = [r for c in chunks for r in c]
    assert flat == list(sr.result.rows) == list(blocking.rows)
    for p, want in golden.items():
        assert (out / p).read_bytes() == want, f"{p} diverged from blocking"


def test_streaming_partial_prefix_on_disk(tmp_path):
    """An abandoned stream leaves a valid rows-so-far prefix on disk."""
    out = tmp_path / "out"
    sr = experiments.run(_stream_spec(str(out)), stream=True, chunk_scenarios=1)
    first = next(sr)
    import json

    with open(out / "metrics.json") as f:
        payload = json.load(f)
    assert payload["rows"] == list(first)


def test_chunk_scenarios_requires_stream(tmp_path):
    with pytest.raises(ValueError, match="chunk_scenarios"):
        experiments.run(_stream_spec(str(tmp_path)), chunk_scenarios=2)


# ------------------------------------------------- partition-aware allocation

PARTITION_LABELS = [
    (BasePolicy.EASY, PSMVariant.PSUS),
    (BasePolicy.FCFS, PSMVariant.PSAS),
    (BasePolicy.EASY, PSMVariant.PSAS_IPM),
]


@pytest.mark.parametrize("base,psm", PARTITION_LABELS)
def test_partition_allocation_oracle_parity(base, psm):
    """allocation='partition' on the 3-group mixed platform: engine ==
    oracle bit-exact, and the constraint actually changes the schedule
    relative to allocation='any' (the test is not vacuous)."""
    plat = mixed_platform_example(16)  # fast(5) / eco(5) / std(6)
    wl = generate_workload(
        GeneratorConfig(n_jobs=60, nb_res=16, max_res=5, seed=1, overrun_prob=0.2)
    )
    cfg = EngineConfig(
        base=base, psm=psm, timeout=300, terminate_overrun=True,
        allocation="partition",
    )
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    tab = schedule_table(s)
    np.testing.assert_array_equal(tab, des.schedule_table())
    assert (tab[:, 0] >= 0).all()  # max_res=5 fits every group: all start
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    tab_any = schedule_table(
        engine.simulate(plat, wl, dataclasses.replace(cfg, allocation="any"))
    )
    assert not np.array_equal(tab, tab_any)


def test_partition_grouped_tables_bit_exact():
    """The grouped-tables fast path honours the partition constraint
    identically to the dense path."""
    plat = mixed_platform_example(16)
    wl = generate_workload(
        GeneratorConfig(n_jobs=60, nb_res=16, max_res=5, seed=1, overrun_prob=0.2)
    )
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=300,
        terminate_overrun=True, allocation="partition",
    )
    dense = engine.simulate(plat, wl, cfg)
    grp = engine.simulate(
        plat, wl, dataclasses.replace(cfg, grouped_tables=True)
    )
    np.testing.assert_array_equal(schedule_table(dense), schedule_table(grp))


def test_partition_oversize_job_fails_to_start():
    """A job wider than every group never starts under
    allocation='partition' (rather than binding across groups), on both
    engines; allocation='any' runs it."""
    plat = mixed_platform_example(16)  # largest group: std(6)
    wl = generate_workload(GeneratorConfig(n_jobs=20, nb_res=16, max_res=5, seed=3))
    big = dataclasses.replace(wl.jobs[5], res=7)
    wl = dataclasses.replace(wl, jobs=wl.jobs[:5] + (big,) + wl.jobs[6:])
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=300,
        allocation="partition",
    )
    tab = schedule_table(engine.simulate(plat, wl, cfg))
    _, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(tab, des.schedule_table())
    assert tab[5, 0] == -1  # never started
    assert (np.delete(tab[:, 0], 5) >= 0).all()  # EASY backfills past it
    tab_any = schedule_table(
        engine.simulate(plat, wl, dataclasses.replace(cfg, allocation="any"))
    )
    assert tab_any[5, 0] >= 0


def test_allocation_validation():
    EngineConfig(allocation="partition")
    with pytest.raises(ValueError):
        EngineConfig(allocation="spread")


def test_experiment_spec_carries_allocation(tmp_path):
    exp = experiments.Experiment(
        name="part", workload={"preset": "fig3_small", "n_jobs": 10},
        platform=16, allocation="partition",
    )
    assert exp.engine_config().allocation == "partition"
    again = experiments.Experiment.from_json(exp.to_json())
    assert again.allocation == "partition"


# ------------------------------------------------------- simulation service

def test_sim_serve_smoke_cache_reuse(tmp_path):
    """Two same-shaped requests through SimService: the second reuses the
    first's compiled grid (all hits, zero misses)."""
    from repro.launch import sim_serve

    sim_serve._smoke(devices=None)


def test_sim_serve_bad_request_is_an_error_response(tmp_path, capsys):
    """A malformed spec produces an error response (and response file)
    without killing the service; a good spec queued alongside still runs."""
    from repro.launch.sim_serve import serve

    req = tmp_path / "req"
    req.mkdir()
    (req / "broken.json").write_text('{"name": "broken"}')  # no workload
    _stream_spec(None).save(str(req / "good.json"))
    responses = serve(str(req), str(tmp_path / "resp"), once=True)
    by_name = {r["request"]: r for r in responses}
    assert by_name["broken"]["status"] == "error"
    assert "error" in by_name["broken"]
    assert by_name["good"]["status"] == "done"
    assert by_name["good"]["rows"] == 4
    assert (tmp_path / "resp" / "broken.response.json").exists()
    assert (tmp_path / "resp" / "good.response.json").exists()


# --------------------------------------------- multi-device (subprocess) lanes

def test_sharded_grid_six_by_four_bit_exact():
    """Acceptance grid: 6 schedulers x 4 timeouts on 8 forced host devices
    — one compile, rows and on-disk bytes identical to the 1-device run."""
    run_subprocess(
        textwrap.dedent(
            """
            import json, pathlib, tempfile
            import jax
            assert jax.device_count() == 8
            from repro import experiments
            from repro.core.policy import scheduler_labels

            six = tuple(l for l in scheduler_labels() if "AlwaysOn" not in l)
            out = pathlib.Path(tempfile.mkdtemp()) / "out"
            exp = experiments.Experiment(
                name="shard6x4",
                workload={"preset": "fig3_small", "n_jobs": 30},
                platform=16,
                schedulers=six,
                timeouts=(60, 300, 600, None),
                out=str(out),
            )
            ref = experiments.run(exp)
            golden = {p: (out / p).read_bytes()
                      for p in ("metrics.json", "rows.csv")}
            sh = experiments.run(exp, devices=8)
            assert sh.n_compiles == 1, sh.n_compiles
            assert list(sh.rows) == list(ref.rows)
            for p, want in golden.items():
                assert (out / p).read_bytes() == want, p
            print("OK", len(sh.rows))
            """
        ),
        n_devices=8,
    )


def test_sharded_pad_rows_masked_and_oracle_parity():
    """K=5 grid on 8 devices (pad 3 rows): pad rows are dropped on gather,
    per-scenario results are bit-exact vs unsharded AND vs the sequential
    oracle."""
    run_subprocess(
        textwrap.dedent(
            """
            import numpy as np
            import jax
            assert jax.device_count() == 8
            from repro.core import engine
            from repro.core.metrics import schedule_table
            from repro.core.ref.pydes import run_pydes
            from repro.core.types import BasePolicy, EngineConfig, PSMVariant
            from repro.workloads.generator import GeneratorConfig, generate_workload
            from repro.workloads.platform import PlatformSpec

            plat = PlatformSpec(nb_nodes=16)
            wl = generate_workload(GeneratorConfig(n_jobs=20, nb_res=16, seed=0))
            cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS)
            scenarios = [60, 120, 300, 600, None]   # K=5 -> pad to 8
            ref = engine.sweep(plat, wl, scenarios, cfg)
            sh = engine.sweep(plat, wl, scenarios, cfg, devices=8)
            assert sh.devices == 8
            assert int(sh.states.energy.shape[0]) == 5  # pad rows masked
            for fld in ("energy", "job_start", "job_finish", "t"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref.states, fld)),
                    np.asarray(getattr(sh.states, fld)),
                    err_msg=f"sharded/unsharded diverged in {fld}",
                )
            # oracle parity per scenario row
            import dataclasses
            for i, t in enumerate(scenarios):
                c = dataclasses.replace(
                    cfg, timeout=t if t is not None else None)
                _, des = run_pydes(plat, wl, c)
                row = jax.tree_util.tree_map(lambda a: a[i], sh.states)
                np.testing.assert_array_equal(
                    schedule_table(row), des.schedule_table(),
                    err_msg=f"scenario {t} diverged from oracle",
                )
            print("OK")
            """
        ),
        n_devices=8,
    )


def test_sharded_rl_training_runs():
    """A2C/PPO data-parallel rollout on 8 devices: envs shard over the
    mesh, gradients pmean-reduce, training produces finite losses."""
    run_subprocess(
        textwrap.dedent(
            """
            import jax
            import numpy as np
            assert jax.device_count() == 8
            from repro.core.rl.a2c import A2CConfig, train_a2c
            from repro.core.rl.ppo import PPOConfig, train_ppo
            from repro.core.rl.env import EnvConfig, shard_env_batch, rollout_mesh
            from repro.core.types import BasePolicy, EngineConfig, PSMVariant
            from repro.workloads.generator import GeneratorConfig, generate_workload
            from repro.workloads.platform import PlatformSpec

            plat = PlatformSpec(nb_nodes=16)
            wl = generate_workload(GeneratorConfig(n_jobs=16, nb_res=16, seed=0))
            ecfg = EnvConfig(engine=EngineConfig(
                psm=PSMVariant.RL, base=BasePolicy.EASY,
                rl_decision_interval=600))

            params, history = train_a2c(
                plat, [wl], ecfg,
                A2CConfig(n_envs=16, n_steps=4, n_updates=2, seed=0),
                devices=8)
            assert np.isfinite(history[-1]["loss"])

            params, history = train_ppo(
                plat, [wl], ecfg,
                PPOConfig(n_envs=16, n_steps=4, n_minibatches=2,
                          n_epochs=1, n_updates=2, seed=0),
                devices=8)
            assert np.isfinite(history[-1]["loss"])

            # env-batch sharding validation
            import jax.numpy as jnp
            x = jnp.zeros((16, 3))
            xs = shard_env_batch(x, 8)
            assert xs.sharding.spec == jax.sharding.PartitionSpec("env")
            try:
                shard_env_batch(jnp.zeros((15, 3)), 8)
            except ValueError as e:
                assert "shard evenly" in str(e)
            else:
                raise AssertionError("indivisible env batch not rejected")
            print("OK")
            """
        ),
        n_devices=8,
    )
