"""Training substrate: optimizers, schedules, accumulation-equivalence,
compression, stragglers, elastic batch planning, data determinism."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.compression import (
    COMPRESSORS,
    error_feedback_apply,
    error_feedback_init,
    int8_compress,
    topk_compress,
)
from repro.training.elastic import plan_batch, shrink_env_axis, grow_env_axis
from repro.training.optimizer import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    sgd,
)
from repro.training.stragglers import StepWatchdog, WatchdogConfig, attribute

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------ optimizer

def quadratic_loss(params):
    return sum(
        jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(params)
    )


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(lr=0.1, momentum=0.9),
    lambda: adamw(lr=0.1),
    lambda: adafactor(lr=0.5),
])
def test_optimizers_descend_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray(RNG.normal(size=(16, 16)) * 2, jnp.float32)}
    state = opt.init(params)
    l0 = float(quadratic_loss(params))
    for _ in range(60):
        grads = jax.grad(quadratic_loss)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(quadratic_loss(params)) < 0.2 * l0


def test_adamw_bf16_moments_track_f32():
    params = {"w": jnp.asarray(RNG.normal(size=(64,)), jnp.float32)}
    g = {"w": jnp.asarray(RNG.normal(size=(64,)), jnp.float32)}
    o32 = adamw(lr=1e-2, moment_dtype=jnp.float32)
    o16 = adamw(lr=1e-2, moment_dtype=jnp.bfloat16)
    s32, s16 = o32.init(params), o16.init(params)
    u32, _ = o32.update(g, s32, params)
    u16, _ = o16.update(g, s16, params)
    np.testing.assert_allclose(
        np.asarray(u32["w"]), np.asarray(u16["w"]), rtol=2e-2, atol=2e-3
    )


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90 + 160), rel=1e-6)
    _, n2 = clip_by_global_norm(clipped, 1e9)
    assert float(n2) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(f(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(f(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)


def test_grad_accumulation_equivalence():
    """accum=4 over batch 8 == accum=1, same update (f32 grads averaged)."""
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.training.train_step import (
        TrainStepConfig,
        make_optimizer,
        make_train_step,
    )

    cfg = get_arch("internlm2-1.8b", reduced=True).replace(remat=False)
    model = build_model(cfg)
    opt = make_optimizer("adamw", 1e-3)
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)
    }
    p0 = model.init(jax.random.PRNGKey(0))
    s1 = jax.jit(make_train_step(model, opt, TrainStepConfig(accum_steps=1)))
    s4 = jax.jit(make_train_step(model, opt, TrainStepConfig(accum_steps=4)))
    p1, _, m1 = s1(p0, opt.init(p0), batch)
    p4, _, m4 = s4(p0, opt.init(p0), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-3, rtol=5e-3,
        )


# ---------------------------------------------------------------- compression

def test_int8_compression_error_bounded():
    g = {"w": jnp.asarray(RNG.normal(size=(128, 128)), jnp.float32)}
    gq = int8_compress(g)
    rel = float(
        jnp.linalg.norm(gq["w"] - g["w"]) / jnp.linalg.norm(g["w"])
    )
    assert rel < 0.02


def test_topk_keeps_largest():
    g = {"w": jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)}
    gs = topk_compress(g, fraction=0.1)
    nz = int(jnp.sum(gs["w"] != 0))
    assert abs(nz - int(0.1 * 64 * 64)) <= 64  # ties at threshold
    kept_min = float(jnp.min(jnp.abs(gs["w"][gs["w"] != 0])))
    dropped_max = float(jnp.max(jnp.abs(jnp.where(gs["w"] == 0, g["w"], 0))))
    assert kept_min >= dropped_max - 1e-6


def test_error_feedback_is_lossless_over_time():
    """Sum of sent + final residual == sum of true gradients."""
    g_total = jnp.zeros((32, 32), jnp.float32)
    sent_total = jnp.zeros((32, 32), jnp.float32)
    st = error_feedback_init({"w": g_total})
    comp = functools.partial(topk_compress, fraction=0.05)
    for i in range(10):
        g = {"w": jnp.asarray(RNG.normal(size=(32, 32)), jnp.float32)}
        g_total = g_total + g["w"]
        sent, st = error_feedback_apply(st, g, comp)
        sent_total = sent_total + sent["w"]
    np.testing.assert_allclose(
        np.asarray(sent_total + st.residual["w"]),
        np.asarray(g_total),
        atol=1e-4,
    )


def test_compression_in_train_step_smoke():
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.training.train_step import (
        TrainStepConfig,
        make_optimizer,
        make_train_step,
    )

    cfg = get_arch("internlm2-1.8b", reduced=True)
    model = build_model(cfg)
    opt = make_optimizer("adamw", 1e-3)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    p0 = model.init(jax.random.PRNGKey(0))
    for name in COMPRESSORS:
        step = jax.jit(
            make_train_step(model, opt, TrainStepConfig(compression=name))
        )
        _, _, m = step(p0, opt.init(p0), batch)
        assert np.isfinite(float(m["loss"]))


# ------------------------------------------------------------------ watchdog

def test_watchdog_fires_on_sustained_slowdown():
    clock = {"t": 0.0}
    times = [1.0] * 10 + [5.0] * 5  # sustained 5x slowdown
    it = iter(times)
    fired = []

    def fake_clock():
        return clock["t"]

    wd = StepWatchdog(
        WatchdogConfig(threshold=2.0, patience=3, warmup_steps=2),
        on_straggler=lambda s, dt, base: fired.append(s),
        clock=fake_clock,
    )
    for dt in times:
        wd.start()
        clock["t"] += dt
        wd.stop()
    assert wd.fired >= 1
    assert fired  # callback invoked


def test_watchdog_tolerates_transients():
    clock = {"t": 0.0}
    wd = StepWatchdog(
        WatchdogConfig(threshold=2.0, patience=3, warmup_steps=1),
        clock=lambda: clock["t"],
    )
    pattern = [1.0, 1.0, 6.0, 1.0, 1.0, 6.0, 1.0]  # isolated spikes
    for dt in pattern:
        wd.start()
        clock["t"] += dt
        wd.stop()
    assert wd.fired == 0


def test_attribute_stragglers():
    times = np.asarray([1.0, 1.1, 0.9, 1.0, 3.5, 1.05])
    idx, med = attribute(times)
    assert idx == [4]


# -------------------------------------------------------------------- elastic

def test_plan_batch_spills_to_accumulation():
    p = plan_batch(global_batch=256, dp_degree=8, max_per_device=8)
    assert p.per_device * p.accum_steps * p.dp_degree == 256
    assert p.per_device <= 8
    p2 = plan_batch(global_batch=256, dp_degree=4, max_per_device=8)
    assert p2.per_device * p2.accum_steps * p2.dp_degree == 256


def test_env_axis_resize():
    tree = {"x": jnp.arange(8.0)[:, None] * jnp.ones((8, 3))}
    small = shrink_env_axis(tree, 5)
    assert small["x"].shape == (5, 3)
    big = grow_env_axis(small, 8)
    assert big["x"].shape == (8, 3)


# ----------------------------------------------------------------------- data

def test_token_stream_determinism_and_sharding():
    from repro.data.pipeline import TokenStream

    s = TokenStream(vocab_size=100, seq_len=16, global_batch=8, seed=1)
    a = s.batch_at(5)
    b = s.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], s.batch_at(6)["tokens"])
    # host sharding partitions the batch deterministically
    h0 = TokenStream(100, 16, 8, seed=1, host_id=0, num_hosts=2)
    h1 = TokenStream(100, 16, 8, seed=1, host_id=1, num_hosts=2)
    assert h0.batch_at(5)["tokens"].shape == (4, 16)
    assert not np.array_equal(h0.batch_at(5)["tokens"], h1.batch_at(5)["tokens"])


def test_batch_iterator_prefetch():
    from repro.data.pipeline import TokenStream, make_batch_iterator

    s = TokenStream(vocab_size=50, seq_len=8, global_batch=2, seed=0)
    it = make_batch_iterator(s, start_index=3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], s.batch_at(3)["tokens"])
    second = next(it)
    np.testing.assert_array_equal(second["tokens"], s.batch_at(4)["tokens"])
    it.close()
