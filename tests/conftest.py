"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
host's real (single) device; multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def repo_root():
    return REPO


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run python code in a clean subprocess with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={res.returncode})\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
        )
    return res.stdout


@pytest.fixture(scope="session")
def small_platform():
    from repro.workloads.platform import PlatformSpec

    return PlatformSpec(nb_nodes=16)


@pytest.fixture(scope="session")
def small_workload():
    from repro.workloads.generator import GeneratorConfig, generate_workload

    return generate_workload(GeneratorConfig(n_jobs=80, nb_res=16, seed=7))
