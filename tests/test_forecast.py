"""Forecast-driven proactive power management (core/SEMANTICS.md §Forecast).

Covers: the metamorphic zero-knowledge guarantees (``horizon=0`` and
``alpha=0`` Forecast stacks are bit-exact with their reactive base — engine
superset program, specialized single-run, and oracle, all three), engine ==
oracle parity for live predictors across stacks (incl. the DVFS pre-ramp),
the scheduler x horizon one-compile sweep, the experiments-layer forecast
axis, the label registry, and the config validation guards.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.policy import Forecast, from_label, scheduler_labels
from repro.core.ref.pydes import run_pydes
from repro.core.types import EngineConfig
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec, dvfs_platform_example

FC_LABELS = (
    "EASY PSUS+Forecast",
    "FCFS PSUS+Forecast",
    "EASY PSAS+IPM+Forecast",
    "EASY Forecast",
)


def _wl(n_jobs=60, seed=11, **kw):
    kw.setdefault("overrun_prob", 0.2)
    return generate_workload(
        GeneratorConfig(n_jobs=n_jobs, nb_res=16, seed=seed, **kw)
    )


def _plat():
    return PlatformSpec(nb_nodes=16, t_switch_on=120, t_switch_off=180)


# ------------------------------------- metamorphic zero-knowledge identity

def _base_label(label: str) -> str:
    base = label.replace("+Forecast", "")
    return base.replace(" Forecast", " AlwaysOn")


@pytest.mark.parametrize("label", FC_LABELS)
@pytest.mark.parametrize(
    "kw",
    [dict(forecast_horizon=0), dict(forecast_horizon=None),
     dict(forecast_horizon=900, forecast_alpha=0.0)],
    ids=["h=0", "h=None", "alpha=0"],
)
def test_zero_knowledge_forecast_is_bit_exact_with_reactive_base(label, kw):
    """``horizon=0`` (predicts nothing) and ``alpha=0`` (EWMAs frozen at
    their inits) make rule 10 a provable no-op: schedules and the f32
    energy ledger are bit-exact with the reactive base, on the specialized
    single-run path, the traced superset program, and the oracle."""
    plat, wl = _plat(), _wl()
    gb, gp = from_label(_base_label(label))
    fb, fp = from_label(label)
    shared = dict(timeout=240, terminate_overrun=True)
    golden = engine.simulate(
        plat, wl, EngineConfig(base=gb, policy=gp, **shared)
    )
    cfg = EngineConfig(base=fb, policy=fp, **shared, **kw)
    for specialize in (True, False):  # DCE'd single-run AND superset program
        s = engine.simulate(plat, wl, cfg, specialize=specialize)
        np.testing.assert_array_equal(
            schedule_table(s), schedule_table(golden)
        )
        np.testing.assert_array_equal(
            np.asarray(s.energy), np.asarray(golden.energy)
        )
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(des.schedule_table(), schedule_table(golden))


def test_zero_knowledge_dvfs_preramp_is_identity():
    """The DVFS pre-ramp composes into the identity too: a zero-horizon
    DVFS+Forecast stack matches plain DVFS bit-exactly (schedule AND the
    per-mode ledgers)."""
    plat, wl = dvfs_platform_example(16), _wl()
    gb, gp = from_label("EASY DVFS")
    fb, fp = from_label("EASY DVFS+Forecast")
    golden = engine.simulate(
        plat, wl, EngineConfig(base=gb, policy=gp, timeout=240)
    )
    cfg = EngineConfig(base=fb, policy=fp, timeout=240, forecast_horizon=0)
    s = engine.simulate(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), schedule_table(golden))
    np.testing.assert_array_equal(
        np.asarray(s.energy), np.asarray(golden.energy)
    )
    np.testing.assert_array_equal(
        np.asarray(s.mode_energy), np.asarray(golden.mode_energy)
    )


# -------------------------------------------------- live-predictor parity

@pytest.mark.parametrize("label", FC_LABELS)
@pytest.mark.parametrize("horizon", [300, 1800])
def test_forecast_oracle_parity(label, horizon):
    """Live predictors: engine == oracle bit-exact schedules and energy
    within the f32-Kahan tolerance, across stacks and horizons."""
    plat, wl = _plat(), _wl()
    base, pol = from_label(label)
    cfg = EngineConfig(base=base, policy=pol, timeout=120,
                       forecast_horizon=horizon)
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    assert m.makespan_s == m_ref.makespan_s


def test_forecast_dvfs_preramp_oracle_parity():
    """The pre-ramp path (rule 10 driving rule 9's shared install+rescale
    tail) stays bit-exact across engines, mode ledgers included."""
    plat, wl = dvfs_platform_example(16), _wl()
    base, pol = from_label("EASY DVFS+Forecast")
    cfg = EngineConfig(base=base, policy=pol, timeout=240,
                       forecast_horizon=900)
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    np.testing.assert_allclose(
        np.asarray(m.mode_residency_s),
        np.asarray(m_ref.mode_residency_s),
        rtol=1e-5,
    )


def test_forecast_actually_wakes_nodes_proactively():
    """A live predictor must *do* something: on a bursty arrival stream the
    +Forecast stack switches on more nodes than its reactive base (the
    n_switch_on counter counts rules 7/8/10 wake-ups) and the schedule
    diverges — while remaining in lockstep with the oracle."""
    plat, wl = _plat(), _wl()
    gb, gp = from_label("EASY PSUS")
    golden = engine.simulate(
        plat, wl, EngineConfig(base=gb, policy=gp, timeout=120)
    )
    base, pol = from_label("EASY PSUS+Forecast")
    cfg = EngineConfig(base=base, policy=pol, timeout=120,
                       forecast_horizon=600)
    s = engine.simulate(plat, wl, cfg)
    assert int(np.asarray(s.n_switch_on)) > int(np.asarray(golden.n_switch_on))
    assert not np.array_equal(schedule_table(s), schedule_table(golden))
    _, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())


def test_forecast_predictor_state_updates():
    """The EWMA state really moves off its inits with alpha > 0 and stays
    frozen with alpha = 0 (the identity's mechanism, checked directly)."""
    plat, wl = _plat(), _wl(n_jobs=30, seed=7)
    base, pol = from_label("EASY PSUS+Forecast")
    live = engine.simulate(
        plat, wl,
        EngineConfig(base=base, policy=pol, timeout=120,
                     forecast_horizon=600, forecast_alpha=0.5),
    )
    assert float(np.asarray(live.fc_gap)) < float(2**30)
    assert float(np.asarray(live.fc_res)) > 0.0
    assert int(np.asarray(live.fc_prev_t)) >= 0
    frozen = engine.simulate(
        plat, wl,
        EngineConfig(base=base, policy=pol, timeout=120,
                     forecast_horizon=600, forecast_alpha=0.0),
    )
    assert float(np.asarray(frozen.fc_gap)) == float(2**30)
    assert float(np.asarray(frozen.fc_res)) == 0.0


# ----------------------------------------------- one-compile horizon sweep

def test_scheduler_x_forecast_grid_one_compile():
    """Schedulers x policy stacks x forecast horizons: ONE compiled program
    (horizons are traced EngineConst operands), rows bit-exact with their
    per-config specialized compiles."""
    plat, wl = _plat(), _wl(n_jobs=40, seed=2)
    cfg = EngineConfig(timeout=300, window=28)
    scenarios = [
        "EASY PSUS",
        "EASY PSUS+Forecast",
        {"scheduler": "EASY PSUS+Forecast", "forecast_horizon": 600},
        {"scheduler": "EASY PSUS+Forecast", "forecast_horizon": 1800},
        {"scheduler": "EASY PSAS+IPM+Forecast", "forecast_horizon": 600},
        {"scheduler": "FCFS PSUS", "timeout": 900},
    ]
    batch = engine.sweep(plat, wl, scenarios, cfg)
    if batch.n_compiles is not None:
        assert batch.n_compiles == 1
    singles = [
        ("EASY PSUS", None, 300),
        ("EASY PSUS+Forecast", None, 300),
        ("EASY PSUS+Forecast", 600, 300),
        ("EASY PSUS+Forecast", 1800, 300),
        ("EASY PSAS+IPM+Forecast", 600, 300),
        ("FCFS PSUS", None, 900),
    ]
    for i, (label, horizon, timeout) in enumerate(singles):
        base, pol = from_label(label)
        single = engine.simulate(
            plat, wl,
            EngineConfig(base=base, policy=pol, timeout=timeout, window=28,
                         forecast_horizon=horizon),
        )
        np.testing.assert_array_equal(
            schedule_table(batch.state_at(i)), schedule_table(single),
            err_msg=f"{label} h={horizon}",
        )
    # rows 1 (no horizon -> 0) and 0 (reactive base) are the identity pair
    np.testing.assert_array_equal(
        schedule_table(batch.state_at(1)), schedule_table(batch.state_at(0))
    )


def test_experiment_forecast_axis():
    """The declarative ``forecasts`` axis: one compiled program, a
    ``forecast`` rows column, and the h=0 rows equal to the reactive base
    per label."""
    from repro.experiments import Experiment, run as run_exp

    exp = Experiment(
        name="fc-axis",
        workload={"preset": "fig3_small", "n_jobs": 40},
        platform=16,
        schedulers=("EASY PSUS", "EASY PSUS+Forecast"),
        timeouts=(120,),
        forecasts=(0, 1800),
    )
    res = run_exp(exp)
    if res.n_compiles is not None:
        assert res.n_compiles == 1
    assert [r["forecast"] for r in res.rows] == [0, 1800, 0, 1800]
    by = {(r["scheduler"], r["forecast"]): r for r in res.rows}
    b0 = by[("EASY PSUS", 0)]
    f0 = by[("EASY PSUS+Forecast", 0)]
    assert b0["total_energy_kwh"] == f0["total_energy_kwh"]
    assert b0["mean_wait_s"] == f0["mean_wait_s"]
    # a trivial (None,) axis keeps the legacy row shape
    legacy = dataclasses.replace(exp, forecasts=(None,),
                                 schedulers=("EASY PSUS",))
    assert all("forecast" not in sc for sc in legacy.grid())


def test_experiment_forecast_single_point_specialized_path():
    """A 1-point grid with a forecast entry takes the specialized
    ``engine.simulate`` path and still honors the horizon."""
    from repro.experiments import Experiment, run as run_exp

    spec = dict(
        name="fc-single",
        workload={"preset": "fig3_small", "n_jobs": 40},
        platform=16,
        schedulers=("EASY PSUS+Forecast",),
        timeouts=(120,),
    )
    r_h = run_exp(Experiment(forecasts=(1800,), **spec)).rows[0]
    r_0 = run_exp(Experiment(forecasts=(0,), **spec)).rows[0]
    assert r_h["forecast"] == 1800 and r_0["forecast"] == 0
    assert r_h["total_energy_kwh"] != r_0["total_energy_kwh"]


# ------------------------------------------------- registry + validation

def test_forecast_label_registry():
    assert from_label("EASY Forecast")[1] == Forecast()
    assert from_label("easy psus+forecast")[1].forecast
    # +DVFS / +Forecast stack in either order, onto any base
    a = from_label("FCFS PSAS+IPM+DVFS+Forecast")[1]
    b = from_label("FCFS PSAS+IPM+Forecast+DVFS")[1]
    assert a == b and a.dvfs and a.forecast
    assert from_label("EASY DVFS+Forecast")[1].psm_label() == "DVFS+Forecast"
    assert from_label("EASY RL:groups+Forecast")[1].psm_label() == (
        "RL:groups+Forecast"
    )
    labels = scheduler_labels(include_forecast=True)
    assert "EASY Forecast" in labels and "EASY PSUS+Forecast" in labels
    with pytest.raises(KeyError, match="did you mean"):
        from_label("EASY PSUS+Forcast")


def test_forecast_config_validation():
    with pytest.raises(ValueError, match="forecast_alpha"):
        EngineConfig(forecast_alpha=1.5)
    with pytest.raises(ValueError, match="forecast_horizon"):
        EngineConfig(forecast_horizon=-1)
    from repro.experiments import Experiment

    spec = dict(name="x", workload="preset:fig3_small", platform=8)
    with pytest.raises(ValueError, match="forecast horizon"):
        Experiment(forecasts=(-5,), **spec)
    with pytest.raises(ValueError, match="forecasts axis"):
        Experiment(forecasts=(), **spec)


def test_forecast_policy_fields_are_fallback_defaults():
    """``Forecast(horizon=..., alpha=...)`` seed the traced operands when
    the EngineConfig leaves them unset; an explicit EngineConfig horizon
    wins (core/SEMANTICS.md §Forecast)."""
    plat = _plat()
    pol = Forecast(horizon=450, alpha=0.5)
    const = engine.make_const(plat, EngineConfig(policy=pol))
    assert int(np.asarray(const.forecast_horizon)) == 450
    assert float(np.asarray(const.forecast_alpha)) == 0.5
    const2 = engine.make_const(
        plat, EngineConfig(policy=pol, forecast_horizon=60)
    )
    assert int(np.asarray(const2.forecast_horizon)) == 60


def test_sim_driver_runs_forecast_label(tmp_path):
    from repro.launch.sim import run as sim_run

    out = str(tmp_path / "run")
    res = sim_run(
        {
            "workload": "preset:fig3_small",
            "platform": 16,
            "scheduler": "EASY PSUS+Forecast",
            "timeout": 120,
            "forecast_horizon": 600,
            "gantt": False,
            "out": out,
        }
    )
    assert res["scheduler"] == "EASY PSUS+Forecast"
    assert res["total_energy_kwh"] > 0
