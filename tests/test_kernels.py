"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dt):
    return dict(atol=3e-2, rtol=3e-2) if dt == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- flash attn

FLASH_CASES = [
    # b, sq, sk, h, kh, hd, causal
    (1, 128, 128, 2, 2, 64, True),
    (2, 256, 256, 4, 2, 64, True),
    (1, 256, 256, 8, 1, 128, True),   # MQA
    (2, 128, 384, 4, 4, 64, False),   # cross-ish, sk > sq
    (1, 384, 256, 2, 2, 128, True),   # sq > sk
    (1, 128, 320, 4, 2, 64, True),    # sk not a block multiple (tail pad)
]


@pytest.mark.parametrize("b,sq,sk,h,kh,hd,causal", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(b, sq, sk, h, kh, hd, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, sk, kh, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, sk, kh, hd)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_matches_xla_twin():
    """Kernel == the model stack's chunked-XLA implementation."""
    from repro.models.layers import attention_chunked

    q = jnp.asarray(RNG.normal(size=(2, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 256, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    twin = attention_chunked(q, k, v, causal=True, chunk=128)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(twin), atol=2e-5, rtol=2e-5
    )


def test_flash_attention_grad_path_falls_back():
    """Ragged shapes route to the reference (wrapper contract)."""
    q = jnp.asarray(RNG.normal(size=(1, 100, 2, 64)), jnp.float32)  # 100 % 128 != 0
    k = jnp.asarray(RNG.normal(size=(1, 100, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 100, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ------------------------------------------------------------------ ssd scan

SSD_CASES = [
    # b, s, h, dk, dv, chunk
    (1, 128, 1, 32, 32, 32),
    (2, 256, 2, 64, 64, 64),
    (1, 256, 4, 32, 128, 128),
    (2, 128, 2, 128, 64, 128),  # chunk == S
]


@pytest.mark.parametrize("b,s,h,dk,dv,chunk", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_reference(b, s, h, dk, dv, chunk, dtype):
    q = jnp.asarray(RNG.normal(size=(b, s, h, dk)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, h, dk)) * 0.3, dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, h, dv)), dtype)
    g = jnp.asarray(-np.abs(RNG.normal(size=(b, s, h)) * 0.05), jnp.float32)
    y, hT = ops.ssd_scan(q, k, v, g, chunk=chunk, interpret=True)
    y_ref, hT_ref = ref.gla_reference(q, k, v, g)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(hT), np.asarray(hT_ref), atol=1e-4, rtol=1e-3
    )


def test_ssd_scan_matches_xla_twin():
    from repro.models.ssm import chunked_gla

    q = jnp.asarray(RNG.normal(size=(2, 256, 2, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 256, 2, 32)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 256, 2, 64)), jnp.float32)
    g = jnp.asarray(-np.abs(RNG.normal(size=(2, 256, 2)) * 0.05), jnp.float32)
    y, hT = ops.ssd_scan(q, k, v, g, chunk=64, interpret=True)
    y_twin, hT_twin = chunked_gla(q, k, v, g, chunk=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_twin), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_twin), atol=1e-5, rtol=1e-5)


def test_ssd_scan_decay_extremes():
    """g = 0 (no decay: running sum) and strongly negative (memoryless)."""
    b, s, h, dk, dv = 1, 128, 1, 16, 16
    q = jnp.ones((b, s, h, dk), jnp.float32) * 0.1
    k = jnp.ones((b, s, h, dk), jnp.float32) * 0.1
    v = jnp.asarray(RNG.normal(size=(b, s, h, dv)), jnp.float32)
    for gval in (0.0, -30.0):
        g = jnp.full((b, s, h), gval, jnp.float32)
        y, _ = ops.ssd_scan(q, k, v, g, chunk=32, interpret=True)
        y_ref, _ = ref.gla_reference(q, k, v, g)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


# ---------------------------------------------------------------- event fuse

@pytest.mark.parametrize("e,n", [(1, 16), (8, 64), (37, 200), (64, 128)])
def test_event_fuse_matches_reference(e, n):
    state = jnp.asarray(RNG.integers(0, 5, (e, n)), jnp.int32)
    until = jnp.asarray(RNG.integers(0, 100000, (e, n)), jnp.int32)
    t = jnp.asarray(RNG.integers(0, 50000, (e,)), jnp.int32)
    power = jnp.asarray([9.0, 190.0, 190.0, 190.0, 9.0], jnp.float32)
    d, nx = ops.event_fuse(state, until, t, power, interpret=True)
    d_ref, nx_ref = ref.event_fuse_reference(state, until, t, power)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nx), np.asarray(nx_ref))


def test_event_fuse_matches_engine_semantics():
    """Kernel semantics == engine.next_time's transition term + power draw."""
    from repro.core import engine
    from repro.core.types import BasePolicy, EngineConfig, PSMVariant
    from repro.workloads.generator import GeneratorConfig, generate_workload
    from repro.workloads.platform import PlatformSpec

    plat = PlatformSpec(nb_nodes=32)
    wl = generate_workload(GeneratorConfig(n_jobs=20, nb_res=32, seed=9))
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=60)
    const = engine.make_const(plat, cfg)
    s = engine.init_state(plat, wl, cfg)
    s = engine.process_batch(s, const, cfg)
    # advance a few batches to populate transitions
    for _ in range(10):
        nt = engine.next_time(s, const, cfg)
        if int(nt) >= 2**30:
            break
        s = engine.process_batch(s._replace(t=nt), const, cfg)
    # const.power is per-node [N, 5]; the fused kernel takes the shared
    # per-state table, which on this homogeneous platform is any row
    table = const.power[0]
    d, nx = ops.event_fuse(
        s.node_state[None], s.node_until[None], s.t[None], table,
        interpret=True,
    )
    want_draw = float(jnp.sum(table[s.node_state]))
    assert float(d[0]) == pytest.approx(want_draw, rel=1e-6)


@pytest.mark.parametrize("e,n", [(1, 16), (8, 64), (37, 200), (64, 128)])
def test_event_fuse_ledger_matches_reference(e, n):
    state = jnp.asarray(RNG.integers(0, 5, (e, n)), jnp.int32)
    until = jnp.asarray(RNG.integers(0, 100000, (e, n)), jnp.int32)
    t = jnp.asarray(RNG.integers(0, 50000, (e,)), jnp.int32)
    power = jnp.asarray([9.0, 190.0, 190.0, 190.0, 9.0], jnp.float32)
    d, nx = ops.event_fuse_ledger(state, until, t, power, interpret=True)
    d_ref, nx_ref = ref.event_fuse_ledger_reference(state, until, t, power)
    assert d.shape == (e, 8)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nx), np.asarray(nx_ref))
    # columns beyond the 5 live states (incl. PAD_STATE) must stay zero
    np.testing.assert_array_equal(np.asarray(d[:, 5:]), 0.0)


def test_event_fuse_pad_poisoning():
    """Non-multiple-of-128 N and non-multiple-of-block_e E: the pad rows
    (PAD_STATE, until=INF) must contribute 0 to every histogram column and
    never win the min — for both the scalar and the ledger variant."""
    e, n = 13, 131  # E % block_e != 0, N % LANES != 0
    state = jnp.asarray(RNG.integers(0, 5, (e, n)), jnp.int32)
    until = jnp.asarray(RNG.integers(0, 100000, (e, n)), jnp.int32)
    t = jnp.asarray(RNG.integers(0, 50000, (e,)), jnp.int32)
    power = jnp.asarray([9.0, 190.0, 190.0, 190.0, 9.0], jnp.float32)
    d, nx = ops.event_fuse(state, until, t, power, block_e=8, interpret=True)
    d_ref, nx_ref = ref.event_fuse_reference(state, until, t, power)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nx), np.asarray(nx_ref))
    dl, nxl = ops.event_fuse_ledger(
        state, until, t, power, block_e=8, interpret=True
    )
    dl_ref, nxl_ref = ref.event_fuse_ledger_reference(state, until, t, power)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(dl_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nxl), np.asarray(nxl_ref))


def test_event_fuse_no_transitions_is_inf():
    """With no switching node anywhere, the masked min must be INF_TIME —
    a poisoned pad column would instead leak a finite until."""
    from repro.core.types import IDLE, INF_TIME

    e, n = 5, 131
    state = jnp.full((e, n), IDLE, jnp.int32)
    until = jnp.asarray(RNG.integers(0, 1000, (e, n)), jnp.int32)
    t = jnp.zeros((e,), jnp.int32)
    power = jnp.asarray([9.0, 190.0, 190.0, 190.0, 9.0], jnp.float32)
    _, nx = ops.event_fuse(state, until, t, power, interpret=True)
    np.testing.assert_array_equal(np.asarray(nx), int(INF_TIME))
    _, nxl = ops.event_fuse_ledger(state, until, t, power, interpret=True)
    np.testing.assert_array_equal(np.asarray(nxl), int(INF_TIME))


def test_event_fuse_zero_size_fallback():
    """E == 0 and N == 0 short-circuit (jnp.min over an empty axis errors;
    the contract is draw 0 / next INF)."""
    from repro.core.types import INF_TIME

    power = jnp.asarray([9.0, 190.0, 190.0, 190.0, 9.0], jnp.float32)
    for e, n in [(0, 16), (4, 0), (0, 0)]:
        state = jnp.zeros((e, n), jnp.int32)
        until = jnp.zeros((e, n), jnp.int32)
        t = jnp.zeros((e,), jnp.int32)
        d, nx = ops.event_fuse(state, until, t, power, interpret=True)
        assert d.shape == (e,) and nx.shape == (e,)
        dl, nxl = ops.event_fuse_ledger(state, until, t, power, interpret=True)
        assert dl.shape == (e, 8) and nxl.shape == (e,)
        if e:
            np.testing.assert_array_equal(np.asarray(d), 0.0)
            np.testing.assert_array_equal(np.asarray(nx), int(INF_TIME))
            np.testing.assert_array_equal(np.asarray(dl), 0.0)
            np.testing.assert_array_equal(np.asarray(nxl), int(INF_TIME))


def test_event_fuse_untileable_falls_back():
    """A node row too wide to tile into VMEM routes to the jnp reference
    (wrapper contract, like flash_attention's ragged fallback)."""
    assert not ops._event_untileable(8, 4096, 8)
    assert ops._event_untileable(2, 131073, 8)  # pads to 131200 lanes
    e, n = 2, 131073
    state = jnp.asarray(RNG.integers(0, 5, (e, n)), jnp.int32)
    until = jnp.asarray(RNG.integers(0, 100000, (e, n)), jnp.int32)
    t = jnp.asarray(RNG.integers(0, 50000, (e,)), jnp.int32)
    power = jnp.asarray([9.0, 190.0, 190.0, 190.0, 9.0], jnp.float32)
    d, nx = ops.event_fuse(state, until, t, power, interpret=True)
    d_ref, nx_ref = ref.event_fuse_reference(state, until, t, power)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nx), np.asarray(nx_ref))
    dl, nxl = ops.event_fuse_ledger(state, until, t, power, interpret=True)
    dl_ref, nxl_ref = ref.event_fuse_ledger_reference(state, until, t, power)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(dl_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nxl), np.asarray(nxl_ref))


@pytest.mark.parametrize("e,n,g", [(1, 16, 1), (8, 64, 3), (37, 200, 5),
                                   (64, 128, 2)])
def test_event_fuse_occ_matches_reference(e, n, g):
    state = jnp.asarray(RNG.integers(0, 5, (e, n)), jnp.int32)
    until = jnp.asarray(RNG.integers(0, 100000, (e, n)), jnp.int32)
    t = jnp.asarray(RNG.integers(0, 50000, (e,)), jnp.int32)
    gid = jnp.asarray(RNG.integers(0, g, (n,)), jnp.int32)
    occ, nx = ops.event_fuse_occ(state, until, t, gid, g, interpret=True)
    occ_ref, nx_ref = ref.event_fuse_occ_reference(state, until, t, gid, g)
    assert occ.shape == (e, g, 8)
    np.testing.assert_array_equal(np.asarray(occ), np.asarray(occ_ref))
    np.testing.assert_array_equal(np.asarray(nx), np.asarray(nx_ref))
    # every node lands in exactly one (group, state) cell...
    np.testing.assert_array_equal(np.asarray(occ.sum(axis=(1, 2))), float(n))
    # ...and never in the dead columns (incl. PAD_STATE = 7)
    np.testing.assert_array_equal(np.asarray(occ[:, :, 5:]), 0.0)


def test_event_fuse_occ_pad_poisoning():
    """Pad rows get gid 0 / PAD_STATE, so they land in the dead cell
    (0, 7) — which is sliced off by the dead-column contract, never
    inflating a live group-0 count; pad untils must not win the min."""
    e, n, g = 13, 131, 3  # E % block_e != 0, N % LANES != 0
    state = jnp.asarray(RNG.integers(0, 5, (e, n)), jnp.int32)
    until = jnp.asarray(RNG.integers(0, 100000, (e, n)), jnp.int32)
    t = jnp.asarray(RNG.integers(0, 50000, (e,)), jnp.int32)
    gid = jnp.asarray(RNG.integers(0, g, (n,)), jnp.int32)
    occ, nx = ops.event_fuse_occ(
        state, until, t, gid, g, block_e=8, interpret=True
    )
    occ_ref, nx_ref = ref.event_fuse_occ_reference(state, until, t, gid, g)
    np.testing.assert_array_equal(np.asarray(occ), np.asarray(occ_ref))
    np.testing.assert_array_equal(np.asarray(nx), np.asarray(nx_ref))
    np.testing.assert_array_equal(np.asarray(occ[:, :, 5:]), 0.0)


def test_event_fuse_occ_matches_engine_occupancy():
    """The [G, 5] slice of the kernel histogram == the engine's dense
    scatter-add `_occupancy` on a real mixed-platform state."""
    from repro.core import engine
    from repro.core.types import BasePolicy, EngineConfig, PSMVariant
    from repro.workloads.generator import GeneratorConfig, generate_workload
    from repro.workloads.platform import mixed_platform_example

    plat = mixed_platform_example(12)
    wl = generate_workload(GeneratorConfig(n_jobs=20, nb_res=12, seed=9))
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=60)
    const = engine.make_const(plat, cfg)
    s = engine.init_state(plat, wl, cfg)
    s = engine.process_batch(s, const, cfg)
    for _ in range(6):
        nt = engine.next_time(s, const, cfg)
        if int(nt) >= 2**30:
            break
        s = engine.process_batch(s._replace(t=nt), const, cfg)
    g = plat.n_groups()
    occ, _ = ops.event_fuse_occ(
        s.node_state[None], s.node_until[None], s.t[None],
        const.group_id, g, interpret=True,
    )
    want = engine._occupancy(s, const)
    np.testing.assert_array_equal(
        np.asarray(occ[0, :, :5]).astype(np.int32), np.asarray(want)
    )


def test_event_fuse_occ_zero_size_fallback():
    from repro.core.types import INF_TIME

    for e, n in [(0, 16), (4, 0), (0, 0)]:
        state = jnp.zeros((e, n), jnp.int32)
        until = jnp.zeros((e, n), jnp.int32)
        t = jnp.zeros((e,), jnp.int32)
        gid = jnp.zeros((n,), jnp.int32)
        occ, nx = ops.event_fuse_occ(state, until, t, gid, 3, interpret=True)
        assert occ.shape == (e, 3, 8) and nx.shape == (e,)
        if e:
            np.testing.assert_array_equal(np.asarray(occ), 0.0)
            np.testing.assert_array_equal(np.asarray(nx), int(INF_TIME))


def test_flash_attention_zero_size_short_circuit():
    """Zero-length queries/keys return zeros instead of tripping the
    `sq % min(block_q, sq)` tiling test with a ZeroDivisionError
    (SL004 kernel contract)."""
    for bq, bk in [(0, 16), (4, 0), (0, 0)]:
        q = jnp.zeros((2, bq, 4, 8), jnp.float32)
        k = jnp.zeros((2, bk, 4, 8), jnp.float32)
        v = jnp.zeros((2, bk, 4, 8), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True, interpret=True)
        assert out.shape == (2, bq, 4, 8) and out.dtype == q.dtype
        np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_ssd_scan_zero_size_short_circuit():
    """An empty sequence returns (empty y, zeros h_final) — the recurrence
    never leaves its h0 = zeros state (SL004 kernel contract)."""
    b, h, dk, dv = 2, 3, 8, 4
    q = jnp.zeros((b, 0, h, dk), jnp.float32)
    k = jnp.zeros((b, 0, h, dk), jnp.float32)
    v = jnp.zeros((b, 0, h, dv), jnp.float32)
    g = jnp.zeros((b, 0, h), jnp.float32)
    y, hT = ops.ssd_scan(q, k, v, g, interpret=True)
    assert y.shape == (b, 0, h, dv) and y.dtype == v.dtype
    assert hT.shape == (b, h, dk, dv) and hT.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(hT), 0.0)
