"""End-to-end behaviour tests: the paper's system-level claims.

1. Energy/wait trade-off: shorter idle timeout => less energy, more waiting
   (paper Figs. 4/5 shape).
2. Scheduler ordering: EASY dominates FCFS on wait; PSM variants save energy
   vs always-on (paper §3 results direction).
3. The end-to-end train driver recovers from a crash (fault-tolerance path,
   via subprocess).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec

PLAT = PlatformSpec(nb_nodes=32)  # paper Table 3 power model


@pytest.fixture(scope="module")
def sparse_workload():
    # sparse arrivals make idle-energy management matter
    return generate_workload(
        GeneratorConfig(
            n_jobs=60, nb_res=32, mean_interarrival=2500.0,
            mean_runtime=2000.0, seed=42,
        )
    )


def run(cfg, wl):
    s = engine.simulate(PLAT, wl, cfg)
    return metrics_from_state(s, PLAT.power_active)


def test_timeout_energy_wait_tradeoff(sparse_workload):
    """Figs. 4/5: sweeping the shutdown timeout trades energy for waiting."""
    energies, waits = [], []
    for timeout in (300, 1800, 3600):
        m = run(
            EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=timeout),
            sparse_workload,
        )
        energies.append(m.total_energy_j)
        waits.append(m.mean_wait_s)
    # energy grows with timeout (nodes idle longer before sleeping)
    assert energies[0] < energies[-1]
    # waiting shrinks with timeout (fewer cold starts)
    assert waits[0] >= waits[-1]


def test_any_psm_beats_always_on_energy(sparse_workload):
    m_on = run(EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.NONE), sparse_workload)
    for psm in (PSMVariant.PSUS, PSMVariant.PSAS, PSMVariant.PSAS_IPM):
        m = run(
            EngineConfig(base=BasePolicy.EASY, psm=psm, timeout=300),
            sparse_workload,
        )
        assert m.total_energy_j < m_on.total_energy_j, psm


def test_easy_no_worse_wait_than_fcfs():
    wl = generate_workload(
        GeneratorConfig(n_jobs=120, nb_res=32, mean_interarrival=200.0, seed=9)
    )
    m_f = run(EngineConfig(base=BasePolicy.FCFS, psm=PSMVariant.PSUS, timeout=600), wl)
    m_e = run(EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=600), wl)
    assert m_e.mean_wait_s <= m_f.mean_wait_s + 1e-6


def test_ipm_reduces_wait_vs_psus_on_bursty_load():
    """IPM's proactive wake + demand-guarded shutdown should not hurt wait."""
    wl = generate_workload(
        GeneratorConfig(n_jobs=80, nb_res=32, mean_interarrival=600.0, seed=17)
    )
    m_psus = run(
        EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=120), wl
    )
    m_ipm = run(
        EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSAS_IPM, timeout=120), wl
    )
    assert m_ipm.mean_wait_s <= m_psus.mean_wait_s * 1.05


def test_train_driver_crash_recovery(tmp_path):
    """launch/train.py: crash at step 12, restart completes to step 20."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    base = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "internlm2-1.8b", "--reduced",
        "--steps", "20", "--batch", "2", "--seq", "32",
        "--ckpt-every", "5", "--ckpt-dir", str(tmp_path),
        "--log-every", "100",
    ]
    r1 = subprocess.run(
        base + ["--fail-at", "12"], capture_output=True, text=True, env=env,
        cwd=repo, timeout=600,
    )
    assert r1.returncode == 17, r1.stderr  # simulated hard failure
    r2 = subprocess.run(base, capture_output=True, text=True, env=env, cwd=repo, timeout=600)
    assert r2.returncode == 0, r2.stderr
    assert "resumed from step 10" in r2.stdout
    assert '"steps_run": 10' in r2.stdout


def test_serve_driver_smoke():
    from repro.launch.serve import main as serve_main

    res = serve_main(
        [
            "--arch", "whisper-tiny", "--reduced",
            "--requests", "6", "--slots", "2",
            "--prompt-len", "8", "--max-new", "8", "--cache-len", "64",
        ]
    )
    assert res["requests"] == 6
    assert res["total_tokens"] >= 6 * 8 * 0.9
