"""Declarative experiment layer + the traced policy axis.

Covers: Experiment JSON round-trip (incl. golden-file determinism of
metrics.json across reruns), the flag-gated superset program's
bit-exactness vs per-config compiles AND the sequential oracle on
fig3_small for all six scheduler labels, one-compile grids (tier-1 small;
the full 6x4 nightly grid is the `slow` lane asserted by
`make test-nightly`), and the launch CLI --experiment path.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro import experiments
from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.policy import (
    IPM,
    PolicyParams,
    TimeoutSleep,
    from_label,
    scheduler_labels,
)
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig
from repro.workloads.generator import PRESETS, GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec

SIX = tuple(l for l in scheduler_labels() if "AlwaysOn" not in l)


# --------------------------------------------------------------- spec layer

def test_experiment_json_roundtrip():
    exp = experiments.Experiment(
        name="rt",
        workload={"preset": "fig3_small", "n_jobs": 40},
        platform=16,
        schedulers=SIX,
        timeouts=(60, 300, None),
        node_order="cheap",
        terminate_overrun=True,
        replications=2,
        out="out/rt",
    )
    again = experiments.Experiment.from_json(exp.to_json())
    assert again == exp
    # tuples normalize from JSON lists; grid order is scheduler-major
    assert again.schedulers == SIX
    assert again.grid()[0] == {"scheduler": SIX[0], "timeout": 60}
    assert len(again.grid()) == len(SIX) * 3


def test_experiment_rejects_bad_specs():
    with pytest.raises(ValueError, match="did you mean 'schedulers'"):
        experiments.Experiment.from_json(
            json.dumps(
                {"name": "x", "workload": "preset:fig3_small",
                 "platform": 16, "scheduler": ["EASY PSUS"]}
            )
        )
    with pytest.raises(KeyError, match="unknown scheduler label"):
        experiments.Experiment(
            name="x", workload="preset:fig3_small", platform=16,
            schedulers=("EASY TURBO",),
        )
    with pytest.raises(ValueError, match=">= 1 scheduler"):
        experiments.Experiment(
            name="x", workload="preset:fig3_small", platform=16,
            schedulers=(),
        )
    with pytest.raises(ValueError, match="replications"):
        experiments.Experiment(
            name="x", workload="preset:fig3_small", platform=16,
            replications=0,
        )
    with pytest.raises(ValueError, match="seed"):
        # a file-backed workload has no seed axis to replicate over
        experiments.resolve_workload("profiles", replication=1)
    with pytest.raises(ValueError, match="did you mean 'n_jobs'"):
        # typo'd generator-override keys fail at spec construction, not as
        # an opaque dataclasses.replace TypeError at run() time
        experiments.Experiment(
            name="x", platform=16,
            workload={"preset": "fig3_small", "n_job": 40},
        )


def test_run_rejects_injection_that_breaks_the_record(tmp_path):
    """Injected platform/workload objects cannot be combined with spec
    outputs (metrics.json records the spec as the reproduction recipe) or
    with replications > 1 (r >= 1 regenerates from the spec)."""
    exp = experiments.Experiment(
        name="inj", workload={"preset": "fig3_small", "n_jobs": 10},
        platform=8,
    )
    wl = experiments.resolve_workload(exp.workload)
    with pytest.raises(ValueError, match="reproduction recipe"):
        experiments.run(
            dataclasses.replace(exp, out=str(tmp_path)), workload=wl
        )
    with pytest.raises(ValueError, match="replications"):
        experiments.run(
            dataclasses.replace(exp, replications=2), workload=wl
        )


def test_experiment_golden_file_run(tmp_path):
    """load -> run -> metrics.json; rerun of the identical spec produces a
    byte-identical metrics.json (the golden-file anchor: seeded generator +
    one compiled program + deterministic f32 ledger)."""
    spec_path = tmp_path / "exp.json"
    out = tmp_path / "out"
    experiments.Experiment(
        name="golden",
        workload={"preset": "fig3_small", "n_jobs": 50},
        platform=16,
        schedulers=("EASY PSUS", "FCFS PSAS"),
        timeouts=(120, None),
        terminate_overrun=True,
        out=str(out),
    ).save(str(spec_path))

    result = experiments.run_file(str(spec_path))
    assert len(result.rows) == 4
    if result.n_compiles is not None:
        assert result.n_compiles == 1
    with open(out / "metrics.json") as f:
        first = f.read()
    payload = json.loads(first)
    assert payload["experiment"]["name"] == "golden"
    assert [r["scheduler"] for r in payload["rows"]] == [
        "EASY PSUS", "EASY PSUS", "FCFS PSAS", "FCFS PSAS"
    ]
    assert os.path.exists(out / "rows.csv")

    experiments.run_file(str(spec_path))  # golden rerun
    with open(out / "metrics.json") as f:
        assert f.read() == first


def test_replications_advance_the_seed():
    exp = experiments.Experiment(
        name="reps",
        workload={"preset": "fig3_small", "n_jobs": 30},
        platform=16,
        schedulers=("EASY PSUS",),
        timeouts=(300,),
        replications=2,
    )
    result = experiments.run(exp)
    r0, r1 = result.rows
    assert r0["replication"] == 0 and r1["replication"] == 1
    assert r0["total_energy_kwh"] != r1["total_energy_kwh"]


# ------------------------------------------- superset program bit-exactness

@pytest.mark.parametrize("label", SIX)
def test_superset_bit_exact_per_label_fig3(label):
    """The flag-gated superset program vs a per-config compile vs the
    sequential oracle, on fig3_small, for every paper scheduler label:
    schedule tables bit-exact both ways, f32 energy ledger bit-exact vs the
    per-config compile, f64-oracle energy within the Kahan tolerance."""
    wl = generate_workload(
        GeneratorConfig(**{**PRESETS["fig3_small"].__dict__, "n_jobs": 80})
    )
    plat = PlatformSpec(nb_nodes=16)
    cfg = EngineConfig(terminate_overrun=True)
    batch = engine.sweep(
        plat, wl, [{"scheduler": label, "timeout": 180}], cfg
    )
    state = batch.state_at(0)

    base, pol = from_label(label)
    single_cfg = EngineConfig(
        base=base, policy=pol, timeout=180, terminate_overrun=True
    )
    single = engine.simulate(plat, wl, single_cfg)
    np.testing.assert_array_equal(schedule_table(state), schedule_table(single))
    np.testing.assert_array_equal(
        np.asarray(state.energy), np.asarray(single.energy)
    )

    m_ref, des = run_pydes(plat, wl, single_cfg)
    np.testing.assert_array_equal(schedule_table(state), des.schedule_table())
    m = metrics_from_state(state, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    assert m.makespan_s == m_ref.makespan_s


def test_grid_one_compile_small():
    """6 schedulers x 2 timeouts: ONE compiled program, every row bit-exact
    with its per-config compile (the tier-1 sampling of the nightly 6x4
    assertion)."""
    wl = generate_workload(
        GeneratorConfig(**{**PRESETS["fig3_small"].__dict__, "n_jobs": 60})
    )
    plat = PlatformSpec(nb_nodes=16)
    cfg = EngineConfig(terminate_overrun=True, window=32)
    scenarios = [
        {"scheduler": lbl, "timeout": t} for lbl in SIX for t in (90, 600)
    ]
    batch = engine.sweep(plat, wl, scenarios, cfg)
    if batch.n_compiles is not None:
        assert batch.n_compiles == 1
    for i, sc in enumerate(scenarios):
        base, pol = from_label(sc["scheduler"])
        single = engine.simulate(
            plat, wl,
            EngineConfig(base=base, policy=pol, timeout=sc["timeout"],
                         terminate_overrun=True),
        )
        np.testing.assert_array_equal(
            schedule_table(batch.state_at(i)), schedule_table(single),
            err_msg=str(sc),
        )
        np.testing.assert_array_equal(
            np.asarray(batch.state_at(i).energy), np.asarray(single.energy),
            err_msg=str(sc),
        )


@pytest.mark.slow
def test_nightly_full_grid_one_compile():
    """The acceptance grid: 6 schedulers x 4 timeouts through the
    experiment layer, n_compiles == 1, with per-row oracle parity on a
    sample of rows (`make test-nightly`)."""
    exp = experiments.Experiment(
        name="nightly_grid",
        workload={"preset": "fig3_small", "n_jobs": 120},
        platform=16,
        schedulers=SIX,
        timeouts=(60, 300, 900, 1800),
        terminate_overrun=True,
    )
    result = experiments.run(exp)
    assert len(result.rows) == 24
    assert result.n_compiles in (None, 1), (
        f"full grid recompiled: {result.n_compiles} programs"
    )
    wl = experiments.resolve_workload(exp.workload)
    plat = experiments.resolve_platform(exp.platform)
    for row in result.rows[:: 6]:
        base, pol = from_label(row["scheduler"])
        m_ref, _ = run_pydes(
            plat, wl,
            EngineConfig(base=base, policy=pol, timeout=row["timeout"],
                         terminate_overrun=True),
        )
        assert row["total_energy_kwh"] * 3.6e6 == pytest.approx(
            m_ref.total_energy_j, rel=1e-5
        ), row["scheduler"]


# ----------------------------------------------------- policy-axis plumbing

def test_policy_params_lowering():
    assert TimeoutSleep().params(BasePolicy.EASY) == PolicyParams(
        backfill=True, eager_ready=True, sleep_enabled=True,
        ipm_enabled=False, rl_enabled=False, rl_grouped=False,
        dvfs_enabled=False, dvfs_rl=False,
        forecast_enabled=False, forecast_dvfs=False,
    )
    assert IPM().params(BasePolicy.FCFS) == PolicyParams(
        backfill=False, eager_ready=False, sleep_enabled=True,
        ipm_enabled=True, rl_enabled=False, rl_grouped=False,
        dvfs_enabled=False, dvfs_rl=False,
        forecast_enabled=False, forecast_dvfs=False,
    )
    from repro.core.policy import DVFS, AlwaysOn, RLController

    assert AlwaysOn().params(BasePolicy.EASY).sleep_enabled is False
    pp = RLController(grouped=True).params(BasePolicy.EASY)
    assert pp.rl_enabled and pp.rl_grouped and pp.eager_ready
    assert not pp.dvfs_enabled
    pp = DVFS().params(BasePolicy.EASY)
    assert pp.dvfs_enabled and not pp.dvfs_rl and not pp.sleep_enabled
    pp = RLController(dvfs=True).params(BasePolicy.EASY)
    assert pp.dvfs_enabled and pp.dvfs_rl and pp.rl_enabled
    pp = TimeoutSleep(dvfs=True).params(BasePolicy.EASY)
    assert pp.dvfs_enabled and pp.sleep_enabled and not pp.dvfs_rl


def test_sweep_label_and_policy_scenarios():
    """Scenario spellings: a label string and a bare PowerPolicy land on the
    same traced point as the explicit mapping."""
    wl = generate_workload(GeneratorConfig(n_jobs=30, nb_res=16, seed=9))
    plat = PlatformSpec(nb_nodes=16)
    cfg = EngineConfig(base=BasePolicy.EASY, timeout=300)
    batch = engine.sweep(
        plat, wl,
        ["EASY PSAS", TimeoutSleep(transition_aware=True),
         {"scheduler": "EASY PSAS"}],
        cfg,
    )
    e0 = np.asarray(batch.state_at(0).energy)
    np.testing.assert_array_equal(e0, np.asarray(batch.state_at(1).energy))
    np.testing.assert_array_equal(e0, np.asarray(batch.state_at(2).energy))


def test_sweep_jit_cache_is_bounded():
    """The sweep program cache is an LRU of bounded size: a long-lived grid
    search cannot accumulate compiled programs without limit."""
    wl = generate_workload(GeneratorConfig(n_jobs=5, nb_res=8, seed=0))
    plat = PlatformSpec(nb_nodes=8)
    for w in range(engine._SWEEP_CACHE_SIZE + 3):
        engine.sweep(plat, wl, [60], EngineConfig(window=w + 1))
        assert len(engine._SWEEP_FNS) <= engine._SWEEP_CACHE_SIZE
    assert len(engine._SWEEP_FNS) == engine._SWEEP_CACHE_SIZE


def test_cli_experiment_flag(tmp_path):
    """launch/sim.py --experiment runs a spec file end to end."""
    from repro.launch.sim import main as sim_main

    spec = tmp_path / "exp.json"
    experiments.Experiment(
        name="cli",
        workload={"preset": "fig3_small", "n_jobs": 30},
        platform=16,
        schedulers=("EASY PSUS", "EASY PSAS"),
        timeouts=(120,),
        out=str(tmp_path / "out"),
    ).save(str(spec))
    result = sim_main(["--experiment", str(spec)])
    assert len(result.rows) == 2
    assert os.path.exists(tmp_path / "out" / "metrics.json")


def test_unknown_sim_config_key_suggests(tmp_path):
    from repro.launch.sim import run as sim_run

    with pytest.raises(ValueError, match="did you mean 'timeout'"):
        sim_run(
            {"workload": "preset:fig3_small", "platform": 16,
             "timeot": 300, "gantt": False, "out": str(tmp_path)}
        )
    with pytest.raises(ValueError, match="did you mean 'checkpoint'"):
        sim_run(
            {"workload": "preset:fig3_small", "platform": 16,
             "scheduler": "EASY RL", "rl": {"checkpont": "x"},
             "gantt": False, "out": str(tmp_path)}
        )
