"""Runtime per-group DVFS (core/SEMANTICS.md §DVFS).

Covers: the metamorphic single-mode guarantee (a DVFS-enabled run over an
identity mode table is bit-exact with the non-DVFS path — engine == oracle
== pre-DVFS golden — for every scheduler label), multi-mode ladder parity
between both engines, agent-commanded modes (RL:dvfs, in-graph controller
vs oracle rl_policy), the remaining-work rescale rule, mode ledgers, the
scheduler x DVFS one-compile sweep, the platform-schema JSON path, and the
did-you-mean guards.
"""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.policy import DVFS, RLController, from_label, scheduler_labels
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import (
    DvfsProfile,
    NodeGroup,
    PlatformSpec,
    dvfs_platform_example,
    load_platform,
    mixed_platform_example,
    platform_from_groups,
)
from repro.workloads.workload import workload_from_arrays

I32 = jnp.int32

SIX = tuple(l for l in scheduler_labels() if "AlwaysOn" not in l)

DVFS_LABELS = ("EASY DVFS", "FCFS DVFS", "EASY PSUS+DVFS",
               "EASY PSAS+IPM+DVFS")


def _wl(n_jobs=60, seed=11, **kw):
    kw.setdefault("overrun_prob", 0.2)
    return generate_workload(
        GeneratorConfig(n_jobs=n_jobs, nb_res=16, seed=seed, **kw)
    )


# ------------------------------------------- metamorphic single-mode table

@pytest.mark.parametrize("label", SIX)
def test_single_mode_table_is_bit_exact_with_non_dvfs(label):
    """Identity mode table (the default: one entry = the group's base
    operating point): DVFS enabled == DVFS disabled == oracle, bit-exact
    schedules and bit-exact f32 energy ledger, for every scheduler label."""
    plat = mixed_platform_example(16)  # no declared modes -> identity table
    wl = _wl()
    base, pol = from_label(label)
    kw = dict(base=base, timeout=240, terminate_overrun=True,
              node_order="cheap")
    golden = engine.simulate(plat, wl, EngineConfig(policy=pol, **kw))
    cfg_dvfs = EngineConfig(policy=dataclasses.replace(pol, dvfs=True), **kw)
    s = engine.simulate(plat, wl, cfg_dvfs)
    np.testing.assert_array_equal(schedule_table(s), schedule_table(golden))
    np.testing.assert_array_equal(
        np.asarray(s.energy), np.asarray(golden.energy)
    )
    m_ref, des = run_pydes(plat, wl, cfg_dvfs)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    assert m.makespan_s == m_ref.makespan_s


def test_explicit_single_mode_equal_to_base_is_identity():
    """A *declared* one-entry table equal to the base operating point is the
    same identity (the table values, not their absence, are the contract)."""
    wl = _wl(n_jobs=40, seed=3)
    plain = PlatformSpec(nb_nodes=16)
    declared = platform_from_groups(
        (
            NodeGroup(
                count=16,
                dvfs_modes=(DvfsProfile("base", power=190.0, speed=1.0),),
            ),
        )
    )
    cfg = EngineConfig(policy=DVFS(), timeout=300, terminate_overrun=True)
    s_plain = engine.simulate(plain, wl, dataclasses.replace(cfg))
    s_decl = engine.simulate(declared, wl, cfg)
    np.testing.assert_array_equal(
        schedule_table(s_decl), schedule_table(s_plain)
    )
    golden = engine.simulate(
        plain, wl,
        EngineConfig(policy=from_label("EASY AlwaysOn")[1], timeout=300,
                     terminate_overrun=True),
    )
    np.testing.assert_array_equal(
        schedule_table(s_decl), schedule_table(golden)
    )


# ------------------------------------------------- multi-mode ladder parity

@pytest.mark.parametrize("label", DVFS_LABELS)
def test_multi_mode_ladder_oracle_parity(label):
    """Queue-pressure ladder over a real 3-mode table on the mixed platform:
    bit-exact schedules, energy within the Kahan tolerance, and matching
    mode-residency ledgers across engines; modes must actually switch."""
    plat = dvfs_platform_example(16)
    wl = _wl()
    base, pol = from_label(label)
    cfg = EngineConfig(base=base, policy=pol, timeout=240,
                       terminate_overrun=True, node_order="cheap")
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)
    res = np.asarray(m.mode_residency_s)
    np.testing.assert_allclose(
        res, np.asarray(m_ref.mode_residency_s), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(m.energy_by_mode_j),
        np.asarray(m_ref.energy_by_mode_j),
        rtol=1e-5,
    )
    # the ladder really moved: more than one mode has residency somewhere
    assert (res > 0).sum() > res.shape[0], label


def test_mode_ledgers_are_consistent():
    """Residency sums to the accrued horizon per group; energy-by-mode sums
    to the ACTIVE row of the per-group energy ledger."""
    plat = dvfs_platform_example(16)
    wl = _wl(n_jobs=50, seed=4)
    cfg = EngineConfig(policy=DVFS(), node_order="cheap")
    s = engine.simulate(plat, wl, cfg)
    m = metrics_from_state(s, plat)
    res = np.asarray(m.mode_residency_s)  # [G, M]
    horizon = float(np.asarray(s.t))
    np.testing.assert_allclose(res.sum(axis=1), horizon, rtol=1e-5)
    by_mode = np.asarray(m.energy_by_mode_j).sum(axis=1)  # [G]
    active = np.asarray(m.energy_by_group_j)[:, 3]  # ACTIVE column
    np.testing.assert_allclose(by_mode, active, rtol=1e-4)
    # row() exposes the ledgers only when DVFS ran with a real mode choice
    row = m.row()
    assert any(k.startswith("mode_s.") for k in row)
    assert any(k.startswith("mode_kwh.") for k in row)
    row_off = metrics_from_state(
        engine.simulate(plat, wl, EngineConfig()), plat
    ).row()
    assert not any(k.startswith("mode_") for k in row_off)


def test_dvfs_changes_realized_runtimes():
    """With an empty queue the ladder idles at the slowest mode: a lone
    1-node job on a 2x-mode table runs at the slow mode's speed."""
    plat = platform_from_groups(
        (
            NodeGroup(count=4, dvfs_modes=(
                DvfsProfile("slow", power=100.0, speed=0.5),
                DvfsProfile("fast", power=260.0, speed=2.0),
            )),
        )
    )
    wl = workload_from_arrays(
        res=[1], subtime=[0], runtime=[100], reqtime=[500], nb_res=4
    )
    s = engine.simulate(
        plat, wl, EngineConfig(policy=DVFS(), terminate_overrun=True)
    )
    # demand (1) * n_modes (2) // N (4) = 0 -> slow mode, speed 0.5
    assert schedule_table(s)[0, 1] == 200.0
    golden = engine.simulate(plat, wl, EngineConfig(terminate_overrun=True))
    assert schedule_table(golden)[0, 1] == 100.0  # base speed 1.0


# ----------------------------------------------- agent-commanded (RL:dvfs)

def _mode_controllers():
    """Scripted DVFS controller implemented identically for both engines:
    fastest mode while demand is queued, slowest when idle."""

    def jax_ctrl(s, const):
        G = s.rl_on_cmd.shape[0]
        waiting = (s.job_status == 0) & (s.job_subtime <= s.t)
        demand = jnp.sum(jnp.where(waiting, s.job_res, 0))
        mode = jnp.where(demand > 0, const.dvfs_n_modes - 1, 0)
        z = jnp.zeros(G, I32)
        return z, z, mode

    def py_ctrl(des):
        G = des.n_groups
        demand = des._queued_demand()
        mode = [
            int(des.dvfs_n_modes[g]) - 1 if demand > 0 else 0
            for g in range(G)
        ]
        return np.zeros(G, int), np.zeros(G, int), np.asarray(mode)

    return jax_ctrl, py_ctrl


def test_rl_dvfs_controller_oracle_parity():
    jax_ctrl, py_ctrl = _mode_controllers()
    plat = dvfs_platform_example(16)
    wl = _wl(n_jobs=50, seed=5, overrun_prob=0.0)
    cfg = EngineConfig(
        base=BasePolicy.EASY,
        policy=RLController(dvfs=True, controller=jax_ctrl),
        rl_decision_interval=600, node_order="cheap",
        terminate_overrun=True,
    )
    s = engine.simulate(plat, wl, cfg)
    cfg_ref = dataclasses.replace(cfg, policy=RLController(dvfs=True))
    m_ref, des = run_pydes(plat, wl, cfg_ref, rl_policy=py_ctrl)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)


def test_rl_dvfs_rejects_legacy_two_tuple_controller():
    """A (on, off)-only controller under RL:dvfs would silently pin mode 0;
    the arity mismatch must fail loudly at trace time."""
    plat = dvfs_platform_example(16)
    wl = _wl(n_jobs=5, seed=0)
    cfg = EngineConfig(
        policy=RLController(
            dvfs=True,
            controller=lambda s, const: (s.rl_on_cmd * 0, s.rl_off_cmd * 0),
        ),
    )
    with pytest.raises(ValueError, match=r"\(on, off, mode\)"):
        engine.simulate(plat, wl, cfg)


def test_rescale_formula_midrun():
    """A mode flip mid-run rescales the remaining wall time by the f32
    contract expression (checked against a hand computation)."""
    plat = platform_from_groups(
        (
            NodeGroup(count=2, dvfs_modes=(
                DvfsProfile("half", power=100.0, speed=0.5),
                DvfsProfile("base", power=190.0, speed=1.0),
            )),
        )
    )
    # job 0 runs [0, 400) at the mode in force at start; job 1's arrival at
    # t=100 raises demand, flipping the ladder to the fast mode
    wl = workload_from_arrays(
        res=[1, 2], subtime=[0, 100], runtime=[200, 50],
        reqtime=[900, 900], nb_res=2,
    )
    s = engine.simulate(plat, wl, EngineConfig(policy=DVFS()))
    table = schedule_table(s)
    # start at mode 0 (speed .5): eff = 400, finish would be 400.
    # at t=100: demand=2, n_modes=2, N=2 -> mode 1 (speed 1.0);
    # rem = 300, work = 300 * 0.5 = 150, new_rem = 150 -> finish 250.
    assert table[0, 0] == 0.0
    assert table[0, 1] == 250.0
    m_ref, des = run_pydes(plat, wl, EngineConfig(policy=DVFS()))
    np.testing.assert_array_equal(table, des.schedule_table())


def test_terminated_jobs_keep_their_walltime_cap():
    """terminate_overrun: a job capped at reqtime is never rescaled, and a
    rescale that crosses the cap terminates at it (both engines agree)."""
    plat = platform_from_groups(
        (
            NodeGroup(count=2, dvfs_modes=(
                DvfsProfile("half", power=100.0, speed=0.5),
                DvfsProfile("base", power=190.0, speed=1.0),
            )),
        )
    )
    wl = workload_from_arrays(
        res=[1, 2, 1], subtime=[0, 100, 150], runtime=[200, 50, 60],
        reqtime=[220, 900, 900], nb_res=2,
    )
    cfg = EngineConfig(policy=DVFS(), terminate_overrun=True)
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    # job 0 started at mode 0: realized 400 > reqtime 220 -> capped + marked
    table = schedule_table(s)
    assert table[0, 2] == 1.0  # terminated
    assert table[0, 1] == 220.0  # the cap held through later mode flips


# --------------------------------------------------------- sweeps / grids

def test_scheduler_x_dvfs_grid_one_compile():
    """Schedulers x DVFS stacks x mode-table platform variants: ONE compiled
    program, rows bit-exact with their per-config compiles."""
    plat = dvfs_platform_example(16)
    hot = platform_from_groups(
        tuple(
            dataclasses.replace(
                g,
                dvfs_modes=tuple(
                    dataclasses.replace(m, power=1.3 * m.power)
                    for m in g.dvfs_modes
                ),
            )
            for g in plat.groups()
        )
    )
    wl = _wl(n_jobs=40, seed=2)
    cfg = EngineConfig(node_order="cheap", terminate_overrun=True,
                       timeout=300, window=28)
    scenarios = [
        "EASY PSUS",
        "EASY DVFS",
        "FCFS DVFS",
        "EASY PSAS+IPM+DVFS",
        {"scheduler": "EASY DVFS", "timeout": 900},
        {"scheduler": "EASY DVFS", "platform": hot},
    ]
    batch = engine.sweep(plat, wl, scenarios, cfg)
    if batch.n_compiles is not None:
        assert batch.n_compiles == 1
    for i, label in enumerate(["EASY PSUS", "EASY DVFS", "FCFS DVFS",
                               "EASY PSAS+IPM+DVFS"]):
        base, pol = from_label(label)
        single = engine.simulate(
            plat, wl,
            EngineConfig(base=base, policy=pol, timeout=300,
                         node_order="cheap", terminate_overrun=True,
                         window=28),
        )
        np.testing.assert_array_equal(
            schedule_table(batch.state_at(i)), schedule_table(single),
            err_msg=label,
        )
    # the hot mode table was a traced operand: same schedule, more energy
    np.testing.assert_array_equal(
        schedule_table(batch.state_at(5)), schedule_table(batch.state_at(1))
    )
    assert batch[5].total_energy_j > batch[1].total_energy_j


def test_sweep_rejects_mode_table_width_mismatch():
    plat = dvfs_platform_example(16)  # 3 modes per group
    wl = _wl(n_jobs=5, seed=0)
    with pytest.raises(ValueError, match="mode-table width"):
        engine.sweep(
            plat, wl, [mixed_platform_example(16)], EngineConfig()
        )


def test_experiment_platform_axis_with_dvfs(tmp_path):
    """The experiments platform axis crosses DVFS mode tables in one
    program; rows carry the platform name."""
    from repro import experiments

    plat = dvfs_platform_example(16)
    hot = platform_from_groups(
        tuple(
            dataclasses.replace(
                g,
                dvfs_modes=tuple(
                    dataclasses.replace(m, power=1.3 * m.power)
                    for m in g.dvfs_modes
                ),
            )
            for g in plat.groups()
        )
    )
    exp = experiments.Experiment(
        name="dvfs_axis",
        workload={"preset": "fig3_small", "n_jobs": 40},
        platform=plat,
        schedulers=("EASY PSUS", "EASY DVFS"),
        timeouts=(300,),
        platforms={"base": plat, "hot": hot},
        node_order="cheap",
        out=str(tmp_path / "out"),
    )
    again = experiments.Experiment.from_json(exp.to_json())
    assert [n for n, _ in again.platforms] == ["base", "hot"]
    for bad in (["hi"], [128], [("a", 1, 2)]):
        with pytest.raises(ValueError, match="not a .name, spec. pair"):
            experiments.Experiment(
                name="bad", workload="preset:fig3_small", platform=16,
                platforms=bad,
            )
    result = experiments.run(again)
    assert len(result.rows) == 4
    if result.n_compiles is not None:
        assert result.n_compiles == 1
    assert [r["platform"] for r in result.rows] == [
        "base", "hot", "base", "hot"
    ]
    dvfs_rows = [r for r in result.rows if r["scheduler"] == "EASY DVFS"]
    assert dvfs_rows[1]["total_energy_kwh"] > dvfs_rows[0]["total_energy_kwh"]
    with open(tmp_path / "out" / "rows.csv") as f:
        header = f.readline().strip().split(",")
    assert header[:4] == ["scheduler", "timeout", "platform", "replication"]


def test_experiment_rl_checkpoint_entries(tmp_path):
    """RL-checkpoint scenario entries: an RL label rides the grid next to
    baselines, driven by a saved policy."""
    import jax

    from repro import experiments
    from repro.core.rl.env import EnvConfig
    from repro.core.rl.networks import policy_init
    from repro.training.checkpoint import save_policy

    ecfg = EnvConfig()
    params = policy_init(jax.random.PRNGKey(0), ecfg.obs_size, ecfg.n_actions)
    ckpt = str(tmp_path / "policy")
    save_policy(
        ckpt, params, obs_size=ecfg.obs_size, n_actions=ecfg.n_actions,
        feature=ecfg.feature, action=ecfg.action,
        n_levels=ecfg.n_action_levels,
    )
    exp = experiments.Experiment(
        name="rl_entries",
        workload={"preset": "fig3_small", "n_jobs": 40},
        platform=16,
        schedulers=("EASY PSUS", "EASY RL"),
        timeouts=(300,),
        rl={"checkpoint": ckpt, "decision_interval": 600},
    )
    result = experiments.run(exp)
    assert [r["scheduler"] for r in result.rows] == ["EASY PSUS", "EASY RL"]
    assert all(r["total_energy_kwh"] > 0 for r in result.rows)
    with pytest.raises(ValueError, match="checkpoint"):
        experiments.run(dataclasses.replace(exp, rl=None))
    with pytest.raises(ValueError, match="ONE in-graph RL controller"):
        experiments.run(
            dataclasses.replace(exp, schedulers=("EASY RL", "EASY RL:groups"))
        )
    with pytest.raises(ValueError, match="no RL scheduler label"):
        # an rl block without any RL label would silently run baselines only
        experiments.run(dataclasses.replace(exp, schedulers=("EASY PSUS",)))


# ----------------------------------------------------- schema + guards

def test_dvfs_modes_json_roundtrip(tmp_path):
    """node_groups JSON with dvfs_modes loads, round-trips, and the mode
    tables sort ascending by speed with per-group counts."""
    obj = {
        "node_groups": [
            {
                "name": "big",
                "count": 4,
                "states": {"active": {"power": 300.0}},
                "dvfs_modes": [
                    {"name": "turbo", "power": 400.0, "speed": 2.0},
                    {"name": "eco", "power": 150.0, "speed": 0.5},
                ],
            },
            {"name": "small", "count": 4,
             "states": {"active": {"power": 100.0}}},
        ]
    }
    plat = load_platform(obj)
    speed, watts, n = plat.group_dvfs_tables()
    assert plat.n_dvfs_modes() == 2
    np.testing.assert_array_equal(n, [2, 1])
    np.testing.assert_allclose(speed[0], [0.5, 2.0])  # sorted by speed
    np.testing.assert_allclose(watts[0], [150.0, 400.0])
    np.testing.assert_allclose(speed[1], [1.0, 1.0])  # padded base entry
    np.testing.assert_allclose(watts[1], [100.0, 100.0])
    # round trip through to_json / load_platform
    again = load_platform(json.loads(json.dumps(plat.to_json())))
    assert again.groups()[0].dvfs_modes == plat.groups()[0].dvfs_modes


def test_homogeneous_profiles_feed_the_mode_table():
    """Document-level dvfs_profiles are the synthesized group's runtime
    table (and survive the single-group collapse)."""
    plat = PlatformSpec(
        nb_nodes=8,
        dvfs_profiles=(
            DvfsProfile("eco", power=120.0, speed=0.5),
            DvfsProfile("turbo", power=250.0, speed=2.0),
        ),
    )
    speed, watts, n = plat.group_dvfs_tables()
    np.testing.assert_allclose(speed[0], [0.5, 2.0])
    assert int(n[0]) == 2
    collapsed = platform_from_groups(plat.groups())
    assert collapsed.dvfs_profiles == plat.dvfs_profiles


def test_unknown_dvfs_mode_name_did_you_mean():
    with pytest.raises(ValueError, match="did you mean 'turbo'"):
        PlatformSpec(
            nb_nodes=8,
            dvfs_profiles=(DvfsProfile("turbo", power=250.0, speed=2.0),),
            dvfs_mode="trubo",
        )
    with pytest.raises(ValueError, match="duplicate DVFS mode names"):
        NodeGroup(count=2, dvfs_modes=(
            DvfsProfile("eco", power=100.0, speed=0.5),
            DvfsProfile("eco", power=200.0, speed=1.0),
        ))


def test_unknown_scheduler_label_did_you_mean():
    with pytest.raises(KeyError, match="did you mean 'EASY DVFS'"):
        from_label("EASY DVFSS")
    # the registry accepts the new tokens
    assert from_label("EASY DVFS")[1] == DVFS()
    assert from_label("easy rl:dvfs")[1] == RLController(dvfs=True)
    assert from_label("FCFS PSAS+IPM+DVFS")[1].dvfs
    assert "EASY DVFS" in scheduler_labels(include_dvfs=True)
    assert "EASY RL:dvfs" in scheduler_labels(
        include_rl=True, include_dvfs=True
    )


def test_sim_driver_runs_dvfs_label(tmp_path):
    from repro.launch.sim import run as sim_run

    out = str(tmp_path / "run")
    res = sim_run(
        {
            "workload": "preset:fig3_small",
            "platform": 16,
            "scheduler": "EASY DVFS",
            "gantt": False,
            "out": out,
        }
    )
    assert res["scheduler"] == "EASY DVFS"
    assert res["total_energy_kwh"] > 0


def test_rl_dvfs_checkpoint_label_mismatch_errors(tmp_path):
    """A non-DVFS checkpoint must not drive an 'RL:dvfs' scheduler (and
    vice versa) — mode commands would be mis-decoded."""
    import jax

    from repro.core.rl.networks import policy_init
    from repro.launch.sim import run as sim_run
    from repro.training.checkpoint import save_policy

    params = policy_init(jax.random.PRNGKey(0), 20, 9)
    ckpt = str(tmp_path / "pol")
    save_policy(
        ckpt, params, obs_size=20, n_actions=9, feature="compact",
        action="target_fraction", n_levels=9,
    )
    with pytest.raises(ValueError, match="dvfs"):
        sim_run(
            {
                "workload": "preset:fig3_small",
                "platform": 16,
                "scheduler": "EASY RL:dvfs",
                "rl": {"checkpoint": ckpt},
                "gantt": False,
                "out": str(tmp_path / "x"),
            }
        )


# ----------------------------------------------------------- RL plumbing

def test_group_mode_env_episode():
    from repro.core.rl.env import EnvConfig, HPCGymEnv

    plat = dvfs_platform_example(16)
    wl = _wl(n_jobs=12, seed=1, overrun_prob=0.0)
    cfg = EnvConfig(
        engine=EngineConfig(
            policy=RLController(dvfs=True),
            base=BasePolicy.EASY,
            rl_decision_interval=300,
        ),
        action="group_mode",
        feature="compact_dvfs",
        reward="energy_wait",
        n_groups=3,
        n_action_levels=plat.n_dvfs_modes(),
        max_steps=500,
    )
    assert cfg.n_actions == 3 * plat.n_dvfs_modes()
    assert cfg.obs_size == 20 + 9 * 3
    env = HPCGymEnv(plat, wl, cfg)
    obs = env.reset()
    assert obs.shape == (cfg.obs_size,)
    done, steps = False, 0
    while not done and steps < 500:
        obs, r, done, _ = env.step(steps % cfg.n_actions)
        assert np.isfinite(r)
        steps += 1
    assert done
    sim = env.state.sim
    assert (np.asarray(sim.mode_time).sum(axis=1) > 0).all()


def test_group_mode_env_validation():
    from repro.core.rl.env import EnvConfig, HPCGymEnv

    with pytest.raises(ValueError, match="dvfs"):
        EnvConfig(action="group_mode")  # controller not dvfs
    with pytest.raises(ValueError, match="dvfs"):
        EnvConfig(engine=EngineConfig(policy=RLController(dvfs=True)))
    plat = dvfs_platform_example(16)  # 3 modes
    wl = _wl(n_jobs=5, seed=0)
    cfg = EnvConfig(
        engine=EngineConfig(policy=RLController(dvfs=True)),
        action="group_mode", n_groups=3, n_action_levels=5,
    )
    with pytest.raises(ValueError, match="mode-table width"):
        HPCGymEnv(plat, wl, cfg)
