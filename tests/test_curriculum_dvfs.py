"""Curriculum learning (paper ref [7] analogue) + DVFS speed semantics."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.ref.pydes import run_pydes
from repro.core.rl.curriculum import default_curriculum, train_a2c_curriculum
from repro.core.rl.env import EnvConfig
from repro.core.rl.a2c import A2CConfig
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import DvfsProfile, PlatformSpec


# ------------------------------------------------------------------ DVFS

def dvfs_platform(speed):
    return PlatformSpec(
        nb_nodes=8,
        t_switch_on=60,
        t_switch_off=90,
        dvfs_profiles=(
            DvfsProfile("eco", power=120.0, speed=0.5),
            DvfsProfile("turbo", power=250.0, speed=2.0),
        ),
        dvfs_mode={0.5: "eco", 2.0: "turbo", 1.0: None}[speed],
    )


@pytest.mark.parametrize("speed", [0.5, 2.0])
def test_dvfs_speed_scales_runtimes_and_keeps_parity(speed):
    wl = generate_workload(GeneratorConfig(n_jobs=40, nb_res=8, seed=3))
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=120,
        terminate_overrun=True,
    )
    plat = dvfs_platform(speed)
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    # both engines agree under DVFS scaling
    np.testing.assert_array_equal(schedule_table(s), des.schedule_table())
    m = metrics_from_state(s, plat.power_active)
    assert m.total_energy_j == pytest.approx(m_ref.total_energy_j, rel=1e-5)

    # realized runtimes actually scaled: makespan orders with 1/speed
    base = engine.simulate(dvfs_platform(1.0), wl, cfg)
    mb = metrics_from_state(base, 190.0)
    if speed < 1.0:
        assert m.makespan_s > mb.makespan_s
    else:
        assert m.makespan_s < mb.makespan_s


def test_dvfs_turbo_increases_terminations_less():
    """turbo (speed 2) finishes jobs within walltime that overran at eco."""
    wl = generate_workload(
        GeneratorConfig(n_jobs=60, nb_res=8, seed=9, overrun_prob=0.0,
                        overreq_factor=1.3)
    )
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS,
                       terminate_overrun=True)
    m_eco = metrics_from_state(
        engine.simulate(dvfs_platform(0.5), wl, cfg), 120.0
    )
    m_turbo = metrics_from_state(
        engine.simulate(dvfs_platform(2.0), wl, cfg), 250.0
    )
    assert m_turbo.n_terminated <= m_eco.n_terminated


# ------------------------------------------------------------ curriculum

def test_curriculum_stages_ramp_and_train():
    plat = PlatformSpec(nb_nodes=16, t_switch_on=120, t_switch_off=180)
    target = GeneratorConfig(n_jobs=16, nb_res=16, mean_interarrival=300.0, seed=0)
    stages = default_curriculum(target, n_stages=3, updates_per_stage=2)
    assert len(stages) == 3
    inter = [s[0].mean_interarrival for s in stages]
    assert inter[0] > inter[1] > inter[2]
    assert inter[-1] == pytest.approx(300.0)

    ecfg = EnvConfig(
        engine=EngineConfig(
            psm=PSMVariant.RL, base=BasePolicy.EASY, rl_decision_interval=600
        ),
        max_steps=32,
    )
    acfg = A2CConfig(n_envs=4, n_steps=4, n_updates=2)
    params, history = train_a2c_curriculum(plat, ecfg, stages, acfg)
    assert len(history) == 6  # 3 stages x 2 updates
    assert [h["stage"] for h in history] == [0, 0, 1, 1, 2, 2]
    assert all(np.isfinite(h["loss"]) for h in history)
    # params exist and are finite
    assert all(
        np.isfinite(np.asarray(x)).all()
        for x in jax.tree_util.tree_leaves(params)
    )
