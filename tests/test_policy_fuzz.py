"""Differential fuzz over the policy stack: engine == oracle, always.

Random workloads x platforms x composed policy stacks (every registry label
plus deeper ``+DVFS``/``+Forecast`` compositions), each case asserting
bit-exact schedule parity between the vectorized JAX engine and the
sequential oracle AND energy-ledger consistency (total == per-group ==
per-state tilings, within the f32-Kahan-vs-f64 tolerance).

Like ``test_engine_properties.py``, hypothesis is optional: when installed
the strategies fuzz the space; when absent the identical properties still
*execute* against a deterministic seeded corpus drawn from the same
distributions. ``SPARS_FUZZ_CASES`` scales the lane: tier-1 runs the
bounded default, the nightly lane sets 200+ (see .github/workflows).
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import engine
from repro.core.metrics import metrics_from_state, schedule_table
from repro.core.policy import from_label, scheduler_labels
from repro.core.ref.pydes import run_pydes
from repro.core.types import EngineConfig
from repro.workloads.platform import (
    PlatformSpec,
    dvfs_platform_example,
    mixed_platform_example,
)
from repro.workloads.workload import workload_from_arrays

N_CASES = int(os.environ.get("SPARS_FUZZ_CASES", "20"))

# three fixed platform shapes (shapes are compiled structure; the *values*
# — watts, speeds, transition delays, mode tables — are traced operands):
# homogeneous, 3-group heterogeneous, 3-group with real DVFS mode tables
PLATS = (
    PlatformSpec(nb_nodes=8, t_switch_on=120, t_switch_off=180),
    mixed_platform_example(8),
    dvfs_platform_example(8),
)

# every registry label (base schedulers, DVFS, Forecast) plus deeper rule
# compositions the canonical list does not enumerate
LABELS = tuple(scheduler_labels(include_dvfs=True, include_forecast=True)) + (
    "EASY PSAS+IPM+Forecast",
    "EASY PSAS+IPM+DVFS",
    "EASY DVFS+Forecast",
    "FCFS PSUS+DVFS+Forecast",
)

_TIMEOUTS = (None, 30, 240)
_HORIZONS = (0, 120, 900)
_ALPHAS = (0.0, 0.25, 0.9)
_ORDERS = ("id", "cheap", "pack")


def _draw_case(rng):
    """One fuzz case: (platform, workload, config), drawn from an
    np.random.Generator so the hypothesis and seeded-corpus drivers sample
    the identical space."""
    plat = PLATS[int(rng.integers(len(PLATS)))]
    N = plat.nb_nodes
    n = int(rng.integers(3, 15))
    res = rng.integers(1, N + 1, n)
    subtime = np.sort(rng.integers(0, 4001, n))
    runtime = rng.integers(1, 3001, n)
    reqtime = np.maximum(1, runtime + rng.integers(-50, 301, n))
    wl = workload_from_arrays(
        res.tolist(), subtime.tolist(), runtime.tolist(), reqtime.tolist(),
        nb_res=N,
    )
    base, pol = from_label(LABELS[int(rng.integers(len(LABELS)))])
    cfg = EngineConfig(
        base=base,
        policy=pol,
        timeout=_TIMEOUTS[int(rng.integers(len(_TIMEOUTS)))],
        terminate_overrun=bool(rng.integers(2)),
        node_order=_ORDERS[int(rng.integers(len(_ORDERS)))],
        grouped_tables=bool(rng.integers(2)),
        merge_bursts=bool(rng.integers(2)),
        window=16,
        forecast_horizon=int(_HORIZONS[int(rng.integers(len(_HORIZONS)))]),
        forecast_alpha=float(_ALPHAS[int(rng.integers(len(_ALPHAS)))]),
    )
    return plat, wl, cfg


def _check_case(plat, wl, cfg):
    tag = (
        f"{cfg.label()} timeout={cfg.timeout} h={cfg.forecast_horizon} "
        f"a={cfg.forecast_alpha} order={cfg.node_order} "
        f"grouped={cfg.grouped_tables} merge={cfg.merge_bursts} "
        f"overrun={cfg.terminate_overrun} plat={plat.nb_nodes}n/"
        f"{plat.n_groups()}g"
    )
    s = engine.simulate(plat, wl, cfg)
    m_ref, des = run_pydes(plat, wl, cfg)
    # schedule parity: bit-exact starts/finishes/termination verdicts
    np.testing.assert_array_equal(
        schedule_table(s), des.schedule_table(),
        err_msg=f"engine/oracle schedule divergence: {tag}",
    )
    m = metrics_from_state(s, plat)
    assert m.makespan_s == m_ref.makespan_s, tag
    assert m.n_terminated == m_ref.n_terminated, tag
    # energy parity (engine f32 Kahan vs oracle f64)
    assert m.total_energy_j == pytest.approx(
        m_ref.total_energy_j, rel=1e-5, abs=1e-3
    ), tag
    # ledger consistency: the per-group and per-state views tile the total
    assert m.total_energy_j == pytest.approx(
        sum(sum(g) for g in m.energy_by_group_j), rel=1e-5, abs=1e-3
    ), tag
    assert m.total_energy_j == pytest.approx(
        sum(m.energy_by_state_j), rel=1e-5, abs=1e-3
    ), tag
    assert 0.0 <= m.wasted_energy_j <= m.total_energy_j + 1e-6, tag
    # DVFS stacks: the mode ledgers agree across engines too
    if any(sum(row) > 0 for row in m_ref.mode_residency_s):
        np.testing.assert_allclose(
            np.asarray(m.mode_residency_s),
            np.asarray(m_ref.mode_residency_s),
            rtol=1e-5, err_msg=tag,
        )


if HAVE_HYPOTHESIS:

    @settings(max_examples=N_CASES, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_policy_stack_differential_fuzz(seed):
        _check_case(*_draw_case(np.random.default_rng(seed)))

else:

    @pytest.mark.parametrize("case", range(N_CASES))
    def test_policy_stack_differential_fuzz(case):
        # the seed base is arbitrary but fixed: the corpus is reproducible
        # and disjoint from the test_engine_properties corpora
        _check_case(*_draw_case(np.random.default_rng(77_000 + case)))
