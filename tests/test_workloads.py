"""Workload/platform substrate: generator determinism, JSON/SWF round-trips,
paper Table 3 defaults, gantt export."""
import json
import os

import numpy as np
import pytest

from repro.core import engine
from repro.core.gantt import intervals_from_log, write_csv
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig, PSMVariant, STATE_NAMES
from repro.workloads.generator import (
    PRESETS,
    GeneratorConfig,
    generate_workload,
    preset,
)
from repro.workloads.platform import DEFAULT_PLATFORM, PlatformSpec, load_platform
from repro.workloads.workload import Workload, load_workload, parse_swf


def test_generator_deterministic():
    a = generate_workload(GeneratorConfig(n_jobs=50, seed=3))
    b = generate_workload(GeneratorConfig(n_jobs=50, seed=3))
    assert a.to_json() == b.to_json()
    c = generate_workload(GeneratorConfig(n_jobs=50, seed=4))
    assert a.to_json() != c.to_json()


def test_generator_respects_bounds():
    wl = generate_workload(
        GeneratorConfig(n_jobs=200, nb_res=32, min_res=2, max_res=16, seed=0)
    )
    for j in wl.jobs:
        assert 2 <= j.res <= 16
        assert j.runtime >= 1
        assert j.reqtime >= 1
    subs = [j.subtime for j in wl.jobs]
    assert subs == sorted(subs)


def test_power_of_two_preset():
    wl = preset("nasa_ipsc")
    assert wl.nb_res == 128
    for j in wl.jobs:
        assert j.res & (j.res - 1) == 0  # power of two


def test_paper_table3_platform_defaults():
    p = DEFAULT_PLATFORM
    assert p.power_active == 190.0
    assert p.power_sleep == 9.0
    assert p.power_switch_on == 190.0
    assert p.power_switch_off == 9.0
    assert p.t_switch_on == 30 * 60
    assert p.t_switch_off == 45 * 60
    assert PRESETS["cea_curie"].nb_res == 11200
    assert PRESETS["ciemat_euler"].nb_res == 64


def test_platform_json_roundtrip(tmp_path):
    p = PlatformSpec(nb_nodes=48, power_active=200.0, t_switch_on=900)
    path = str(tmp_path / "platform.json")
    p.save(path)
    q = load_platform(path)
    assert q.nb_nodes == 48
    assert q.power_active == 200.0
    assert q.t_switch_on == 900
    assert q.t_switch_off == p.t_switch_off


def test_workload_json_roundtrip(tmp_path):
    wl = generate_workload(GeneratorConfig(n_jobs=20, seed=1))
    path = str(tmp_path / "workload.json")
    wl.save(path)
    wl2 = load_workload(path)
    assert wl.to_json() == wl2.to_json()


def test_parse_swf(tmp_path):
    swf = "\n".join(
        [
            "; MaxProcs: 64",
            "; some header",
            # id submit wait run alloc cpu mem reqproc reqtime reqmem st uid gid exe q part prev think
            "1 0 5 100 4 -1 -1 4 200 -1 1 1 1 1 1 1 -1 -1",
            "2 50 0 300 8 -1 -1 16 400 -1 1 1 1 1 1 1 -1 -1",
            "3 60 0 -1 2 -1 -1 2 100 -1 0 1 1 1 1 1 -1 -1",  # unknown runtime: drop
        ]
    )
    path = str(tmp_path / "trace.swf")
    with open(path, "w") as f:
        f.write(swf)
    wl = parse_swf(path)
    assert wl.nb_res == 64
    assert len(wl) == 2
    assert wl.jobs[0].res == 4
    assert wl.jobs[1].res == 16
    assert wl.jobs[1].reqtime == 400


def test_parse_swf_large_trace(tmp_path):
    """A synthetic >=10k-line SWF trace with the warts of real archive files:
    comment headers, blank lines, ragged short lines, out-of-order job ids
    and subtimes, unknown runtimes, zero-proc rows, and missing reqtimes.
    The parse must round-trip through make_const/init_state untouched."""
    n = 10_000
    lines = [
        "; SWF trace (synthetic)",
        "; Version: 2.2",
        "; MaxProcs: 320",
        "; MaxRuntime: 86400",
        "",
    ]
    # deterministic pseudo-random stream, no RNG state shared with other tests
    def h(i, k):
        return (i * 2654435761 + k * 40503) % 2**16

    kept = 0
    for i in range(n):
        jid = n - i  # ids descending: parser must not assume sorted input
        subtime = h(i, 1) % 50_000  # unsorted: .sorted_by_subtime() fixes
        kind = i % 100
        if kind == 0:
            lines.append(f"{jid} {subtime} 0 17")  # ragged: < 9 fields, skip
            continue
        if kind == 1:
            lines.append("")  # blank line, skip
            continue
        runtime = -1 if kind == 2 else 1 + h(i, 2) % 3600
        procs = 0 if kind == 3 else 1 + h(i, 3) % 320
        reqtime = -1 if kind == 4 else runtime + h(i, 4) % 600
        lines.append(
            f"{jid} {subtime} 10 {runtime} {procs} -1 -1 {procs} {reqtime}"
            " -1 1 1 1 1 1 1 -1 -1"
        )
        if runtime >= 0 and procs > 0:
            kept += 1
    path = str(tmp_path / "big.swf")
    with open(path, "w") as f:
        f.write("\n".join(lines))

    wl = parse_swf(path)
    assert wl.nb_res == 320  # from the MaxProcs header, not the max res
    assert len(wl) == kept
    assert kept >= 9_000
    subs = [j.subtime for j in wl.jobs]
    assert subs == sorted(subs)
    for j in wl.jobs:
        assert 1 <= j.res <= 320
        assert j.runtime >= 1
        assert j.reqtime >= max(j.runtime, 1)  # missing reqtime backfilled

    # round-trips through the engine's static workload arrays
    plat = PlatformSpec(nb_nodes=wl.nb_res)
    cfg = EngineConfig(timeout=60)
    s0 = engine.init_state(plat, wl, cfg)
    assert s0.job_res.shape == (len(wl),)
    np.testing.assert_array_equal(
        np.asarray(s0.job_subtime), np.asarray(subs, np.int32)
    )
    np.testing.assert_array_equal(
        np.asarray(s0.job_res), np.asarray([j.res for j in wl.jobs], np.int32)
    )
    # and a short slice actually simulates to completion
    out = engine.simulate(plat, wl.tail(50), cfg)
    assert not bool(out.truncated)
    assert int(np.min(np.asarray(out.job_start))) >= 0


def test_workload_tail_shifts_time():
    wl = generate_workload(GeneratorConfig(n_jobs=30, seed=5))
    t = wl.tail(10)
    assert len(t) == 10
    assert t.jobs[0].subtime == 0


def test_gantt_csv_export(tmp_path):
    plat = PlatformSpec(nb_nodes=4, t_switch_on=60, t_switch_off=60)
    wl = generate_workload(GeneratorConfig(n_jobs=10, nb_res=4, seed=2))
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=50, record_gantt=True
    )
    s0 = engine.init_state(plat, wl, cfg)
    const = engine.make_const(plat, cfg)
    s, log = engine.run_sim_gantt(s0, const, cfg, max_batches=500)
    ivs = intervals_from_log(log)
    assert ivs, "no intervals recorded"
    # intervals tile the timeline per node without overlap
    by_node = {}
    for t0, t1, nid, st, job in ivs:
        assert t1 > t0
        assert 0 <= st < len(STATE_NAMES)
        by_node.setdefault(nid, []).append((t0, t1))
    for nid, spans in by_node.items():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0
    path = str(tmp_path / "gantt.csv")
    write_csv(ivs, path)
    assert os.path.getsize(path) > 0

    # oracle gantt agrees on ACTIVE intervals
    _, des = run_pydes(
        plat, wl, cfg
    )
    ref_active = sorted(
        (t0, t1, nid, job) for t0, t1, nid, st, job in des.gantt if st == 3
    )
    jax_active = sorted(
        (float(t0), float(t1), nid, job) for t0, t1, nid, st, job in ivs if st == 3
    )
    assert ref_active == jax_active
