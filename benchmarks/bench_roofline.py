"""Roofline table builder (deliverable (g)): reads the dry-run artifacts in
``out/dryrun`` and emits the per-(arch x shape x mesh) table for
EXPERIMENTS.md §Roofline — three terms in seconds, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a one-line what-would-move-it.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

MOVES = {
    "compute": "more accumulation/unroll to raise MXU occupancy, or quantize",
    "memory": "cut HBM traffic: fuse/remat less, shrink optimizer dtype, "
    "larger microbatch to amortize weight reads",
    "collective": "reshard to cut all-gather volume / overlap reduce with compute",
}


def load(out_dir: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        rf = rec["roofline"]
        rows.append(
            dict(
                arch=rec["arch"],
                shape=rec["shape"],
                mesh=rec["mesh"],
                t_compute_s=rf["t_compute_s"],
                t_memory_s=rf["t_memory_s"],
                t_collective_s=rf["t_collective_s"],
                dominant=rf["dominant"],
                compute_fraction=rf["compute_fraction"],
                model_flops_ratio=rec.get("model_flops_ratio"),
                bytes_per_device=rec.get("memory_analysis", {}).get(
                    "temp_size_in_bytes"
                ),
            )
        )
    return rows


def fmt(x, nd=4):
    if x is None:
        return "-"
    if isinstance(x, float):
        return f"{x:.{nd}g}"
    return str(x)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="out/dryrun")
    ap.add_argument("--mesh", default="16x16", help="16x16 | 2x16x16 | all")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    rows = load(args.out_dir)
    if args.mesh != "all":
        rows = [r for r in rows if r["mesh"] == args.mesh]
    if not rows:
        print(f"no dry-run records in {args.out_dir} (run repro.launch.dryrun first)")
        return []
    cols = [
        "arch", "shape", "mesh", "t_compute_s", "t_memory_s",
        "t_collective_s", "dominant", "compute_fraction", "model_flops_ratio",
    ]
    if args.markdown:
        print("| " + " | ".join(cols) + " | next move |")
        print("|" + "---|" * (len(cols) + 1))
        for r in rows:
            print(
                "| " + " | ".join(fmt(r[c]) for c in cols)
                + f" | {MOVES[r['dominant']]} |"
            )
    else:
        print(",".join(cols))
        for r in rows:
            print(",".join(fmt(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    main()
