"""Forecast-policy grid benchmark (core/SEMANTICS.md §Forecast).

Reactive TimeoutSleep vs the same stack with rule 10's EWMA forecast
(``+Forecast``) vs a group-targeted RL controller (``RL:groups``,
random-init checkpoint — the plumbing/throughput comparison, not a trained
agent), replayed on the head of a Curie-class SWF trace through the
experiments layer: scheduler x forecast-horizon as ONE compiled program.

Asserts the two §Forecast contracts on the produced rows:

* one-compile — the whole grid (reactive + forecast horizons + RL) stays a
  single vmapped XLA program (``ExperimentResult.n_compiles == 1``);
* zero-knowledge identity — the ``horizon=0`` forecast row is bit-exact
  with its reactive base row (rule 10 off vs on-but-inert, same label).

Reports per-row energy / mean wait and sweep wall time for the
``forecast`` section of ``BENCH_grid.json``.

    PYTHONPATH=src python -m benchmarks.bench_forecast --jobs 200
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

from repro.workloads.platform import curie_platform
from repro.workloads.traces import synthesize_curie_swf

SCHEDULERS = ("EASY PSUS", "EASY PSUS+Forecast", "EASY RL:groups")


def _random_init_checkpoint(directory: str, n_groups: int) -> str:
    """A group-targeted policy checkpoint with freshly initialized weights
    (the benchmark compares policy-stack plumbing, not trained quality)."""
    import jax

    from repro.core.rl.actions import action_space_size
    from repro.core.rl.features import feature_size
    from repro.core.rl.networks import policy_init
    from repro.training.checkpoint import save_policy

    obs = feature_size("compact")
    n_actions = action_space_size("group_target_fraction", 9, n_groups)
    params = policy_init(jax.random.PRNGKey(0), obs, n_actions)
    save_policy(
        directory, params, obs_size=obs, n_actions=n_actions,
        feature="compact", action="group_target_fraction", n_levels=9,
        grouped=True, n_groups=n_groups,
    )
    return directory


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=200,
                    help="trace-head jobs replayed for every grid point")
    ap.add_argument("--nodes", type=int, default=280,
                    help="scaled-down Curie platform (3-group structure, "
                         "same regime as bench_curie's verify phase)")
    ap.add_argument("--trace", type=int, default=2000,
                    help="synthesized trace length (SWF lines)")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--horizon", type=int, default=1800,
                    help="non-trivial forecast horizon for the grid axis "
                         "(crossed with the horizon=0 identity point)")
    ap.add_argument("--swf", default=None,
                    help="existing SWF trace to replay (default: synthesize "
                         "a Curie-class trace)")
    args = ap.parse_args(argv)

    from repro import experiments

    plat = curie_platform(args.nodes)
    tmp = tempfile.mkdtemp(prefix="bench_forecast_")
    swf = args.swf or synthesize_curie_swf(
        os.path.join(tmp, "curie.swf"), n_jobs=args.trace
    )
    ckpt = _random_init_checkpoint(
        os.path.join(tmp, "policy"), plat.n_groups()
    )
    exp = experiments.Experiment(
        name="forecast_bench",
        workload={"swf": swf, "nb_nodes": args.nodes, "oversize": "clamp",
                  "max_jobs": args.jobs},
        platform=args.nodes,  # superseded by the injected Curie platform
        schedulers=SCHEDULERS,
        timeouts=(args.timeout,),
        forecasts=(0, args.horizon),
        rl={"checkpoint": ckpt, "decision_interval": args.timeout},
        node_order="cheap",
    )

    experiments.run(exp, platform=plat)  # warm-up: compile once
    t0 = time.perf_counter()
    result = experiments.run(exp, platform=plat)
    wall = time.perf_counter() - t0
    assert result.n_compiles in (None, 1), (
        f"the forecast grid recompiled: {result.n_compiles} programs"
    )

    # zero-knowledge identity: per label, the horizon=0 row == the row of
    # the same label with rule 10 contributing nothing else — for the
    # reactive scheduler the forecast axis is inert outright, so both of
    # its rows must agree; for the forecast stack the h=0 row must match
    # the reactive base row bit-exactly (§Forecast)
    def row(scheduler, forecast):
        (r,) = [
            r for r in result.rows
            if r["scheduler"] == scheduler and r["forecast"] == forecast
        ]
        return r

    for fc in (0, args.horizon):
        r = row("EASY PSUS", fc)
        assert r["total_energy_kwh"] == row("EASY PSUS", 0)["total_energy_kwh"]
        assert r["mean_wait_s"] == row("EASY PSUS", 0)["mean_wait_s"]
    h0, base = row("EASY PSUS+Forecast", 0), row("EASY PSUS", 0)
    assert h0["total_energy_kwh"] == base["total_energy_kwh"], (
        "horizon=0 forecast row diverged from its reactive base"
    )
    assert h0["mean_wait_s"] == base["mean_wait_s"]

    rows = [
        {
            "scheduler": r["scheduler"],
            "forecast": r["forecast"],
            "total_energy_kwh": round(r["total_energy_kwh"], 3),
            "mean_wait_s": round(r["mean_wait_s"], 1),
        }
        for r in result.rows
    ]
    out = {
        "n_compiles": result.n_compiles,
        "grid_k": len(result.rows),
        "nodes": args.nodes,
        "bench_jobs": args.jobs,
        "wall_s": round(wall, 3),
        "jobs_per_s": round(len(result.rows) * args.jobs / wall, 1)
        if wall else None,
        "rows": rows,
    }
    print(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    main()
