"""Paper §3.1 large-scale run: CEA-Curie-class platform (11 200 nodes,
1 000 jobs). The paper reports SPARS 312 s vs batsim-py 17 992 s (~57x).

Our repo contains both engines: the sequential Python DES (``pydes`` —
equivalent to the paper's SPARS artifact, already free of Batsim's IPC
overhead) and the vectorized JAX engine. At 11 200 nodes we report:

  * single-simulation wall time for both engines, and
  * the vectorized engine's real advantage — a K-point timeout sweep (or K
    RL environments) as ONE compiled program, which is the many-repeated-
    simulations regime the paper motivates (§4: RL workflows).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import experiments
from repro.core import engine
from repro.core.metrics import metrics_from_state
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import PRESETS, GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec, mixed_platform_example


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=1000)
    ap.add_argument("--nodes", type=int, default=11200)
    ap.add_argument("--oracle-jobs", type=int, default=None,
                    help="jobs for the oracle run (default: same as --jobs)")
    ap.add_argument("--sweep", type=int, default=8, help="vmapped sweep width")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--hetero", action="store_true",
                    help="3-group mixed platform; sweep stays ONE compiled "
                         "program (EngineConst per-node tables are traced "
                         "operands, not static config)")
    ap.add_argument("--assert-beat-oracle", action="store_true",
                    help="fail unless the grouped-tables single run beats "
                         "the sequential pydes oracle (the nightly gate)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the sweep scenario axis across this many "
                         "local devices (default: all of them when more "
                         "than one is visible)")
    ap.add_argument("--assert-sharded-speedup", action="store_true",
                    help="fail unless the sharded sweep beats the "
                         "single-device sweep (the nightly forced-8-device "
                         "gate; needs a >= 64-scenario grid to be fair)")
    args = ap.parse_args(argv)

    gcfg = PRESETS["cea_curie"]
    gcfg = GeneratorConfig(**{
        **gcfg.__dict__,
        "n_jobs": args.jobs,
        # jobs must fit the benched platform when --nodes shrinks it
        "nb_res": min(gcfg.nb_res, args.nodes),
        "max_res": min(gcfg.max_res or gcfg.nb_res, args.nodes),
    })
    wl = generate_workload(gcfg)
    if args.hetero:
        plat = mixed_platform_example(args.nodes)
    else:
        plat = PlatformSpec(nb_nodes=args.nodes)
    # legacy loop shape for the historical baselines (t_jax / t_spec track
    # the same program across PRs); the fused hot loop is timed separately
    cfg = EngineConfig(
        base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=args.timeout,
        node_order="cheap" if args.hetero else "id",
        fused_events=False,
    )
    cfg_fused = dataclasses.replace(cfg, fused_events=True)

    # --- vectorized engine, single simulation (traced superset program) ---
    s0 = engine.init_state(plat, wl, cfg)
    const = engine.make_const(plat, cfg)
    cap = engine.default_batch_cap(len(wl))
    run_j = jax.jit(lambda s, c: engine.run_sim(s, c, cfg, max_batches=cap))
    t0 = time.perf_counter()
    out = run_j(s0, const)
    jax.block_until_ready(out.energy)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = run_j(s0, const)
    jax.block_until_ready(out.energy)
    t_jax = time.perf_counter() - t0
    m = metrics_from_state(out, plat)
    batches = int(out.n_batches)

    # --- single simulation, statically specialized (§Static specialization):
    # the policy flags are closure constants, so XLA DCEs every rule this
    # config turned off; must be bit-exact with the superset program above
    out_spec = engine.simulate(plat, wl, cfg)  # warm-up: compiles once
    t0 = time.perf_counter()
    out_spec = engine.simulate(plat, wl, cfg)  # cached program, no recompile
    jax.block_until_ready(out_spec.energy)
    t_spec = time.perf_counter() - t0
    np.testing.assert_array_equal(
        np.asarray(out_spec.job_start), np.asarray(out.job_start)
    )
    np.testing.assert_array_equal(
        np.asarray(out_spec.energy), np.asarray(out.energy)
    )
    # the point of the fast path (asserted by the nightly lane): folding
    # the flags must beat carrying every rule as a traced jnp.where gate.
    # Single-shot timings are noisy on shared CI; on an inversion,
    # re-measure both once and compare best-of-2 before failing.
    if t_jax > 0.05 and t_spec >= t_jax:  # too-small runs are timer noise
        t0 = time.perf_counter()
        jax.block_until_ready(run_j(s0, const).energy)
        t_jax = min(t_jax, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(engine.simulate(plat, wl, cfg).energy)
        t_spec = min(t_spec, time.perf_counter() - t0)
        assert t_spec < t_jax, (
            f"specialized single run ({t_spec:.3f}s, best of 2) did not "
            f"beat the superset single run ({t_jax:.3f}s, best of 2)"
        )

    # --- single simulation, fused hot loop (SEMANTICS §Hot loop): one event
    # pass per batch (fused draw+min), quiet-batch fast path, early-exit
    # scheduler scan — must stay bit-exact with the legacy loop above
    out_fused = engine.simulate(plat, wl, cfg_fused)  # warm-up: compiles once
    t0 = time.perf_counter()
    out_fused = engine.simulate(plat, wl, cfg_fused)
    jax.block_until_ready(out_fused.energy)
    t_fused = time.perf_counter() - t0
    np.testing.assert_array_equal(
        np.asarray(out_fused.job_start), np.asarray(out.job_start)
    )
    np.testing.assert_array_equal(
        np.asarray(out_fused.energy), np.asarray(out.energy)
    )
    if t_spec > 0.05 and t_fused > t_spec:  # same noise guard as above
        t0 = time.perf_counter()
        jax.block_until_ready(engine.simulate(plat, wl, cfg).energy)
        t_spec = min(t_spec, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(engine.simulate(plat, wl, cfg_fused).energy)
        t_fused = min(t_fused, time.perf_counter() - t0)
        assert t_fused <= t_spec, (
            f"fused single run ({t_fused:.3f}s, best of 2) regressed vs the "
            f"unfused specialized run ({t_spec:.3f}s, best of 2)"
        )

    # --- single simulation, group-indexed tables (SEMANTICS §Group-indexed
    # tables): [G, 5] occupancy reductions + hoisted sort-free allocation
    # order — O(G) per-batch work instead of O(N). Schedule bit-exact with
    # the dense runs above; energy to f32 rounding (different reduce order)
    cfg_grouped = dataclasses.replace(cfg_fused, grouped_tables=True)
    out_grouped = engine.simulate(plat, wl, cfg_grouped)  # warm-up compile
    t0 = time.perf_counter()
    out_grouped = engine.simulate(plat, wl, cfg_grouped)
    jax.block_until_ready(out_grouped.energy)
    t_grouped = time.perf_counter() - t0
    np.testing.assert_array_equal(
        np.asarray(out_grouped.job_start), np.asarray(out.job_start)
    )
    np.testing.assert_allclose(
        np.asarray(out_grouped.energy), np.asarray(out.energy), rtol=1e-6
    )

    # --- vectorized engine, K-point grid in ONE program ---
    # a scheduler x timeout grid through the declarative experiment layer:
    # the policy axis is a traced operand, so mixing FCFS and EASY stacks
    # with the timeout sweep still compiles exactly once
    # exactly K grid points: two schedulers when K divides evenly, else one
    # scheduler x K timeouts (the per-simulation throughput stays comparable
    # across PRs for any --sweep value)
    K = args.sweep
    n_sched = 2 if K >= 2 and K % 2 == 0 else 1
    exp = experiments.Experiment(
        name="bench_scale_grid",
        # mirror the clamps applied to the injected workload above, so the
        # spec stays an accurate reproduction recipe for this grid
        workload={
            "preset": "cea_curie", "n_jobs": args.jobs,
            "nb_res": gcfg.nb_res, "max_res": gcfg.max_res,
        },
        platform=args.nodes,
        schedulers=("EASY PSUS", "FCFS PSAS+IPM")[:n_sched],
        timeouts=tuple(300 + 300 * i for i in range(K // n_sched)),
        node_order=cfg.node_order,
    )
    assert len(exp.schedulers) * len(exp.timeouts) == K
    experiments.run(exp, platform=plat, workload=wl)  # warm-up: compile once
    t0 = time.perf_counter()
    result = experiments.run(exp, platform=plat, workload=wl)
    t_sweep = time.perf_counter() - t0
    # the no-recompile guarantee: the grid's schedulers and timeouts (and,
    # under --hetero, the full per-node power/speed tables) were traced
    # operands of ONE program. n_compiles is None on JAX versions without
    # the _cache_size API
    n_compiles = result.n_compiles
    if n_compiles is not None:
        assert n_compiles == 1, f"grid recompiled: {n_compiles} programs"

    # --- the same grid sharded across local devices (core/SEMANTICS.md
    # §Device-sharded sweeps): one mesh-lowered program, still ONE compile,
    # row-for-row bit-exact vs the single-device sweep. The win compounds
    # from parallel placement AND per-shard while_loop exit — each device's
    # batch loop stops at ITS lanes' horizon instead of the global max, so
    # a divergent grid (spread timeouts) does strictly less work even on
    # one core
    t_sweep_sharded = None
    D = args.devices if args.devices is not None else jax.device_count()
    if D > 1:
        experiments.run(exp, platform=plat, workload=wl, devices=D)  # warm-up
        t0 = time.perf_counter()
        result_sh = experiments.run(exp, platform=plat, workload=wl, devices=D)
        t_sweep_sharded = time.perf_counter() - t0
        assert [tuple(sorted(r.items())) for r in result_sh.rows] == [
            tuple(sorted(r.items())) for r in result.rows
        ], "sharded sweep rows are not bit-exact vs the single-device sweep"
        if result_sh.n_compiles is not None:
            assert result_sh.n_compiles == 1, (
                f"sharded grid recompiled: {result_sh.n_compiles} programs"
            )
        if args.assert_sharded_speedup:
            if t_sweep_sharded >= t_sweep:  # best-of-2 noise guard
                t0 = time.perf_counter()
                experiments.run(exp, platform=plat, workload=wl)
                t_sweep = min(t_sweep, time.perf_counter() - t0)
                t0 = time.perf_counter()
                experiments.run(exp, platform=plat, workload=wl, devices=D)
                t_sweep_sharded = min(
                    t_sweep_sharded, time.perf_counter() - t0
                )
            assert t_sweep_sharded < t_sweep, (
                f"sharded {K}-scenario sweep ({t_sweep_sharded:.2f}s, "
                f"{D} devices) did not beat the single-device sweep "
                f"({t_sweep:.2f}s)"
            )

    # --- sequential Python oracle (the paper's SPARS engine class) ---
    oracle_jobs = args.oracle_jobs or args.jobs
    wl_o = (
        wl
        if oracle_jobs == args.jobs
        else generate_workload(GeneratorConfig(**{**gcfg.__dict__, "n_jobs": oracle_jobs}))
    )
    t0 = time.perf_counter()
    m_ref, _ = run_pydes(plat, wl_o, cfg)
    t_oracle = (time.perf_counter() - t0) * (args.jobs / oracle_jobs)

    dev = abs(m.total_energy_j - m_ref.total_energy_j) / m_ref.total_energy_j \
        if oracle_jobs == args.jobs else float("nan")

    print(
        f"nodes={args.nodes} jobs={args.jobs} batches={batches} "
        f"platform={'hetero[3 groups]' if args.hetero else 'homogeneous'} "
        f"sweep_programs={n_compiles}"
    )
    print(f"pydes_single_run_s={t_oracle:.2f}"
          + ("" if oracle_jobs == args.jobs else " (extrapolated)"))
    print(f"jax_single_run_s={t_jax:.2f} (first incl. compile: {t_first:.2f})")
    print(f"jax_single_run_specialized_s={t_spec:.2f} "
          f"({t_jax/t_spec:.1f}x vs the traced superset program)")
    print(f"jax_single_run_fused_s={t_fused:.2f} "
          f"({t_spec/t_fused:.1f}x vs the unfused specialized run, "
          f"{t_oracle/t_fused:.1f}x vs the sequential oracle)")
    print(f"jax_single_run_grouped_s={t_grouped:.2f} "
          f"({t_fused/t_grouped:.1f}x vs the dense fused run, "
          f"{t_oracle/t_grouped:.1f}x vs the sequential oracle)")
    if args.assert_beat_oracle:
        assert t_grouped < t_oracle, (
            f"grouped-tables single run ({t_grouped:.2f}s) did not beat "
            f"the sequential oracle ({t_oracle:.2f}s)"
        )
    print(
        f"jax_{K}way_grid_s={t_sweep:.2f} "
        f"({len(exp.schedulers)} schedulers x {len(exp.timeouts)} timeouts) "
        f"= {t_sweep/K:.2f}s per simulation "
        f"({t_oracle*K/t_sweep:.1f}x vs {K} sequential oracle runs)"
    )
    if t_sweep_sharded is not None:
        print(
            f"jax_{K}way_grid_sharded_s={t_sweep_sharded:.2f} "
            f"({D} devices, bit-exact rows; "
            f"{t_sweep/t_sweep_sharded:.2f}x vs the single-device sweep)"
        )
    if oracle_jobs == args.jobs:
        print(f"energy_rel_dev_vs_oracle={dev:.2e}")
    print(
        f"total_energy_kwh={m.total_energy_j/3.6e6:.1f} "
        f"mean_wait_s={m.mean_wait_s:.0f} utilization={m.utilization:.4f}"
    )
    out = dict(
        t_jax=t_jax, t_jax_spec=t_spec, t_jax_fused=t_fused,
        t_jax_grouped=t_grouped,
        t_oracle=t_oracle, t_sweep=t_sweep,
        batches=batches, n_compiles=n_compiles, grid_k=K, jobs=args.jobs,
        nodes=args.nodes,
    )
    if t_sweep_sharded is not None:
        out.update(t_sweep_sharded=t_sweep_sharded, sweep_devices=D)
    return out


if __name__ == "__main__":
    main()
