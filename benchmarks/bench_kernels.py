"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-path
timing only) vs the XLA twins vs naive references, plus the analytic VMEM /
arithmetic-intensity numbers that justify the BlockSpec choices on TPU.

On-CPU wall times of interpret-mode Pallas are NOT TPU predictions; the
derived columns (FLOPs, bytes, intensity) are hardware-independent and are
the inputs to the §Roofline analysis.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.models.layers import attention_chunked, attention_naive
from repro.models.ssm import chunked_gla, gla_scan_reference


def timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def flash_numbers(b=2, s=2048, h=8, kh=2, hd=128, bq=128, bk=128):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.bfloat16)
    t_naive = timeit(jax.jit(lambda *a: attention_naive(*a, causal=True)), q, k, v)
    t_chunk = timeit(
        jax.jit(lambda *a: attention_chunked(*a, causal=True, chunk=512)), q, k, v
    )
    flops = 4.0 * b * h * s * s * hd * 0.5  # causal half
    vmem_kib = (bq * hd + 2 * bk * hd + bq * hd + 2 * bq * 128) * 4 / 1024
    print(
        f"flash_attention,s={s},xla_naive_ms={t_naive*1e3:.1f},"
        f"xla_chunked_ms={t_chunk*1e3:.1f},kernel_vmem_kib={vmem_kib:.0f},"
        f"causal_gflops={flops/1e9:.1f}"
    )


def gla_numbers(b=2, s=2048, h=4, dk=64, dv=64, chunk=128):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)), jnp.float32)
    g = jnp.asarray(-np.abs(rng.normal(size=(b, s, h)) * 0.05), jnp.float32)
    t_seq = timeit(jax.jit(gla_scan_reference), q, k, v, g)
    t_chunk = timeit(jax.jit(lambda *a: chunked_gla(*a, chunk=chunk)), q, k, v, g)
    # chunked: 2 matmuls of (C,dk)x(dk,C)ish per chunk vs S sequential outer products
    vmem_kib = (chunk * (2 * dk + 2 * dv) + chunk * chunk + dk * dv) * 4 / 1024
    print(
        f"ssd_scan,s={s},xla_sequential_ms={t_seq*1e3:.1f},"
        f"xla_chunked_ms={t_chunk*1e3:.1f},speedup={t_seq/t_chunk:.1f}x,"
        f"kernel_vmem_kib={vmem_kib:.0f}"
    )


def event_numbers(e=4096, n=128):
    rng = np.random.default_rng(0)
    state = jnp.asarray(rng.integers(0, 5, (e, n)), jnp.int32)
    until = jnp.asarray(rng.integers(0, 100000, (e, n)), jnp.int32)
    t = jnp.asarray(rng.integers(0, 50000, (e,)), jnp.int32)
    power = jnp.asarray([9.0, 190.0, 190.0, 190.0, 9.0], jnp.float32)
    t_ref = timeit(jax.jit(ref.event_fuse_reference), state, until, t, power)
    read_mb = 2 * e * n * 4 / 1e6
    print(
        f"event_fuse,envs={e},nodes={n},xla_pair_ms={t_ref*1e3:.2f},"
        f"hbm_read_mb={read_mb:.1f},fused_traffic_ratio=0.5"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=2048)
    args = ap.parse_args(argv)
    flash_numbers(s=args.seq)
    gla_numbers(s=args.seq)
    event_numbers()


if __name__ == "__main__":
    main()
