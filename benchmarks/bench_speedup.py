"""Paper Table 4: runtime of the vectorized JAX engine vs the sequential
Python oracle (the Batsim-like baseline), swept over shutdown timeouts.

The oracle exposes the same counter categories as the paper's breakdown
(sim advance / scheduling / resource / job lifecycle / monitoring / timeout
policy); the JAX engine's whole step is one fused XLA program, so its
breakdown collapses into a single column — which is precisely the paper's
point about removing per-event bookkeeping and IPC overhead.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import engine
from repro.core.metrics import metrics_from_state
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import PRESETS, GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec


def bench(n_jobs: int, timeouts: List[int], preset_name: str = "ciemat_euler"):
    gcfg = PRESETS[preset_name]
    gcfg = GeneratorConfig(
        **{**gcfg.__dict__, "n_jobs": n_jobs}
    )
    wl = generate_workload(gcfg)
    plat = PlatformSpec(nb_nodes=gcfg.nb_res)
    rows = []
    for timeout in timeouts:
        cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=timeout)

        # --- JAX engine (compile once per config; time steady-state run) ---
        s0 = engine.init_state(plat, wl, cfg)
        const = engine.make_const(plat, cfg)
        cap = engine.default_batch_cap(len(wl))
        run_j = jax.jit(lambda s, c: engine.run_sim(s, c, cfg, max_batches=cap))
        out = run_j(s0, const)  # compile + first run
        jax.block_until_ready(out.energy)
        t0 = time.perf_counter()
        out = run_j(s0, const)
        jax.block_until_ready(out.energy)
        t_jax = time.perf_counter() - t0
        m_jax = metrics_from_state(out, plat.power_active)

        # --- Python oracle (Batsim-like sequential engine) ---
        t0 = time.perf_counter()
        m_ref, des = run_pydes(plat, wl, cfg)
        t_ref = time.perf_counter() - t0

        dev = abs(m_jax.total_energy_j - m_ref.total_energy_j) / m_ref.total_energy_j
        rows.append(
            dict(
                timeout=timeout,
                t_pydes_s=round(t_ref, 4),
                t_jax_s=round(t_jax, 4),
                speedup=round(t_ref / t_jax, 1),
                batches=int(out.n_batches),
                energy_rel_dev=f"{dev:.2e}",
                counters={k: v for k, v in des.counters.items()},
            )
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=300)
    ap.add_argument("--timeouts", default="300,1200,2100,3000")
    args = ap.parse_args(argv)
    timeouts = [int(t) for t in args.timeouts.split(",")]
    rows = bench(args.jobs, timeouts)
    print("timeout,t_pydes_s,t_jax_s,speedup,batches,energy_rel_dev")
    for r in rows:
        print(
            f"{r['timeout']},{r['t_pydes_s']},{r['t_jax_s']},{r['speedup']},"
            f"{r['batches']},{r['energy_rel_dev']}"
        )
    return rows


if __name__ == "__main__":
    main()
