"""Benchmark entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick defaults
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
    PYTHONPATH=src python -m benchmarks.run --sections dvfs,rl   # a subset

Besides the console report, writes machine-readable ``BENCH_grid.json``
(per-section wall time, compile count, simulated jobs/s where applicable)
so the performance trajectory is tracked across PRs. With ``--sections``,
untouched sections of an existing report file are preserved (read-modify-
write), so one section can be refreshed without a full rerun.
"""
from __future__ import annotations

import argparse
import json
import os
import time

SECTIONS = ("speedup", "energy_grid", "fig1", "scale", "curie", "rl",
            "dvfs", "forecast", "kernels", "roofline")


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--out", default="BENCH_grid.json",
                    help="machine-readable per-section results")
    ap.add_argument(
        "--sections", default=None,
        help=f"comma-separated subset of {','.join(SECTIONS)}; other "
             "sections of an existing report are preserved",
    )
    args = ap.parse_args()
    t0 = time.time()

    from benchmarks import (
        bench_curie,
        bench_dvfs,
        bench_energy,
        bench_forecast,
        bench_kernels,
        bench_rl,
        bench_roofline,
        bench_scale,
        bench_speedup,
    )

    if args.sections:
        wanted = set(args.sections.split(","))
        unknown = wanted - set(SECTIONS)
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}; "
                     f"known: {', '.join(SECTIONS)}")
    else:
        wanted = set(SECTIONS)

    report = {"full": bool(args.full), "sections": {}}
    if args.sections and os.path.exists(args.out):
        with open(args.out) as f:
            prior = json.load(f)
        if prior.get("full", False) != bool(args.full):
            ap.error(
                f"--sections would merge full={bool(args.full)} numbers "
                f"into a full={prior.get('full', False)} report ({args.out}); "
                "rerun without --sections or delete the report first"
            )
        report["sections"] = prior.get("sections", {})

    def want(name):
        return name in wanted

    # device header stamped into every section (core/SEMANTICS.md
    # §Device-sharded sweeps): numbers measured on 1 CPU device and on a
    # forced-8-device host (or a real accelerator mesh) are not comparable,
    # so the report says which machine shape produced each section
    import jax

    device_header = {
        "devices": jax.device_count(),
        "backend": jax.default_backend(),
        "sharded": jax.device_count() > 1,
    }

    def timed(name, fn, **extra):
        s0 = time.perf_counter()
        ret = fn()
        entry = {
            "wall_s": round(time.perf_counter() - s0, 3),
            **device_header,
            **extra,
        }
        report["sections"][name] = entry
        return ret, entry

    if want("speedup"):
        section("Table 4: engine speedup vs sequential oracle (CIEMAT)")
        speedup_jobs = 1000 if args.full else 300
        timed(
            "speedup",
            lambda: bench_speedup.main(["--jobs", str(speedup_jobs)]),
            jobs=speedup_jobs,
        )

    if want("energy_grid"):
        section("Figs. 4/5: six schedulers x timeout grid (NASA) + validation")
        energy_jobs = 2000 if args.full else 300

        def run_energy():
            return bench_energy.main(
                ["--jobs", str(energy_jobs), "--timeouts", "5,15,30,60",
                 "--validate"]
            )

        (rows, grid_result), entry = timed("energy_grid", run_energy)
        entry.update(
            n_compiles=grid_result.n_compiles,
            grid_rows=len(rows),
            jobs_per_s=round(grid_result.jobs_per_s, 1),
            max_energy_dev=max(r["energy_dev"] for r in rows),
        )

    if want("fig1"):
        section("Fig. 1: same-time batching divergence")
        timed("fig1", lambda: bench_energy.main(["--fig1"]))

    if want("scale"):
        section("CEA-Curie scale (11200 nodes)")

        def run_scale():
            return bench_scale.main(
                ["--jobs", "1000" if args.full else "200",
                 "--sweep", "8" if args.full else "4"]
            )

        scale, entry = timed("scale", run_scale)
        entry.update(
            n_compiles=scale.get("n_compiles"),
            grid_k=scale.get("grid_k"),
            jobs_per_s=round(
                scale["grid_k"] * scale["jobs"] / scale["t_sweep"], 1
            ) if scale.get("t_sweep") else None,
            single_run_s=round(scale["t_jax"], 3),
            single_run_specialized_s=round(scale["t_jax_spec"], 3),
            single_run_fused_s=round(scale["t_jax_fused"], 3),
            single_run_grouped_s=round(scale["t_jax_grouped"], 3),
            oracle_run_s=round(scale["t_oracle"], 3),
        )
        if "t_sweep_sharded" in scale:
            entry.update(
                sweep_sharded_s=round(scale["t_sweep_sharded"], 3),
                sweep_devices=scale["sweep_devices"],
            )

    if want("curie"):
        section("Curie-scale SWF trace replay (group-indexed tables)")

        def run_curie():
            return bench_curie.main(
                ["--jobs", "10000", "--verify-jobs",
                 "120" if not args.full else "300"]
                + (["--full"] if args.full else [])
            )

        curie, entry = timed("curie", run_curie)
        entry.update(
            trace_jobs=curie["trace_jobs"],
            bench_jobs=curie["bench_jobs"],
            nodes=curie["nodes"],
            n_groups=curie["n_groups"],
            verify_labels=curie["verify_labels"],
            single_run_dense_fused_s=round(curie["t_dense_fused"], 3),
            single_run_grouped_s=round(curie["t_grouped"], 3),
            single_run_grouped_merge_s=round(curie["t_grouped_merge"], 3),
        )
        if "t_full_replay_grouped" in curie:
            entry.update(
                full_replay_grouped_s=round(curie["t_full_replay_grouped"], 3),
                full_replay_jobs=curie["full_replay_jobs"],
            )

    if want("rl"):
        section("RL workflow throughput")
        rl, entry = timed(
            "rl",
            lambda: bench_rl.main(
                ["--envs", "256" if args.full else "64",
                 "--steps", "64" if args.full else "16"]
            ),
        )
        if isinstance(rl, dict):
            entry.update(
                {f"steps_per_s_{k}": round(v, 1) for k, v in rl.items()}
            )

    if want("dvfs"):
        section("Runtime DVFS: scheduler x mode-table grid (one compile)")
        dvfs_jobs = 1000 if args.full else 300
        dvfs, entry = timed(
            "dvfs", lambda: bench_dvfs.main(["--jobs", str(dvfs_jobs)])
        )
        entry.update(
            n_compiles=dvfs.get("n_compiles"),
            grid_k=dvfs.get("grid_k"),
            jobs_per_s=dvfs.get("jobs_per_s"),
        )

    if want("forecast"):
        section("Rule 10: reactive vs +Forecast vs RL:groups (Curie head)")
        fc_jobs = 200 if args.full else 120
        fc_nodes = 280 if args.full else 120
        fc, entry = timed(
            "forecast",
            lambda: bench_forecast.main(
                ["--jobs", str(fc_jobs), "--nodes", str(fc_nodes),
                 "--trace", "2000" if args.full else "600"]
            ),
        )
        entry.update(
            n_compiles=fc.get("n_compiles"),
            grid_k=fc.get("grid_k"),
            nodes=fc.get("nodes"),
            bench_jobs=fc.get("bench_jobs"),
            jobs_per_s=fc.get("jobs_per_s"),
        )

    if want("kernels"):
        section("Kernel micro-benchmarks")
        timed(
            "kernels",
            lambda: bench_kernels.main(
                ["--seq", "2048" if args.full else "1024"]
            ),
        )

    if want("roofline"):
        section("Roofline table (from out/dryrun)")
        timed("roofline", lambda: bench_roofline.main(["--mesh", "16x16"]))

    # total is the sum of the recorded sections (consistent under
    # --sections merges, where this run's wall time covers only a subset)
    report["total_wall_s"] = round(
        sum(sec.get("wall_s", 0.0) for sec in report["sections"].values()), 1
    )
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"\nbenchmarks done in {time.time() - t0:.0f}s "
          f"(sections total {report['total_wall_s']:.0f}s; "
          f"machine-readable report -> {args.out})")


if __name__ == "__main__":
    main()
