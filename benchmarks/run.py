"""Benchmark entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick defaults
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
"""
from __future__ import annotations

import argparse
import time


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    t0 = time.time()

    from benchmarks import (
        bench_energy,
        bench_kernels,
        bench_rl,
        bench_roofline,
        bench_scale,
        bench_speedup,
    )

    section("Table 4: engine speedup vs sequential oracle (CIEMAT)")
    bench_speedup.main(["--jobs", "1000" if args.full else "300"])

    section("Figs. 4/5: six schedulers x timeout sweep (NASA) + validation")
    bench_energy.main(
        [
            "--jobs", "2000" if args.full else "300",
            "--timeouts", "5,15,30,60",
            "--validate",
        ]
    )

    section("Fig. 1: same-time batching divergence")
    bench_energy.main(["--fig1"])

    section("CEA-Curie scale (11200 nodes)")
    bench_scale.main(
        ["--jobs", "1000" if args.full else "200",
         "--sweep", "8" if args.full else "4"]
    )

    section("RL workflow throughput")
    bench_rl.main(
        ["--envs", "256" if args.full else "64",
         "--steps", "64" if args.full else "16"]
    )

    section("Kernel micro-benchmarks")
    bench_kernels.main(["--seq", "2048" if args.full else "1024"])

    section("Roofline table (from out/dryrun)")
    bench_roofline.main(["--mesh", "16x16"])

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
