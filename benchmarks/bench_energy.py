"""Paper Figs. 4/5: cumulative energy + mean wait for the six schedulers
across a shutdown-timeout sweep, plus the Batsim-style validation run
(JAX engine vs sequential oracle — the paper's 1%-deviation check) and the
Fig. 1 same-time-batching scenario (--fig1).

The ENTIRE scheduler x timeout grid is ONE compiled program — the traced
policy axis (`repro.experiments` over `engine.sweep`): the sweep the paper
runs as 12 separate processes, and that this repo ran as one-program-per-
scheduler before the policy axis became a traced operand.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro import experiments
from repro.core.policy import from_label, scheduler_labels
from repro.core.ref.pydes import run_pydes
from repro.core.types import BasePolicy, EngineConfig, PSMVariant

# the six timeout-based schedulers of the paper's Figs. 4/5
SCHEDULERS = tuple(
    l for l in scheduler_labels() if "AlwaysOn" not in l
)


def sweep(
    preset_name: str = "nasa_ipsc",
    n_jobs: int = 400,
    timeouts_min=(5, 15, 30, 60),
    validate: bool = False,
):
    from repro.workloads.generator import PRESETS

    exp = experiments.Experiment(
        name=f"fig45_{preset_name}",
        workload={"preset": preset_name, "n_jobs": n_jobs},
        platform=PRESETS[preset_name].nb_res,
        schedulers=SCHEDULERS,
        timeouts=tuple(t * 60 for t in timeouts_min),
    )
    experiments.run(exp)  # warm-up: compile the grid program once
    result = experiments.run(exp)  # timed run -> steady-state jobs_per_s
    assert result.n_compiles in (None, 1), (
        f"the grid recompiled: {result.n_compiles} programs"
    )

    if validate:  # the oracle reruns need the resolved objects
        plat = experiments.resolve_platform(exp.platform)
        wl = experiments.resolve_workload(exp.workload)
    rows = []
    for grid_row in result.rows:
        name, t_s = grid_row["scheduler"], grid_row["timeout"]
        row = dict(
            scheduler=name,
            timeout_min=t_s // 60,
            total_energy_kwh=round(grid_row["total_energy_kwh"], 3),
            wasted_energy_kwh=round(grid_row["wasted_energy_kwh"], 3),
            mean_wait_s=round(grid_row["mean_wait_s"], 1),
            utilization=round(grid_row["utilization"], 4),
        )
        if validate:
            base, pol = from_label(name)
            m_ref, _ = run_pydes(
                plat, wl, EngineConfig(base=base, policy=pol, timeout=t_s)
            )
            row["energy_dev"] = (
                abs(grid_row["total_energy_kwh"] * 3.6e6 - m_ref.total_energy_j)
                / m_ref.total_energy_j
            )
        rows.append(row)
    return rows, result


def fig1():
    """The same-time-batching scenario (paper Fig. 1) as a benchmark row."""
    from repro.workloads.platform import PlatformSpec
    from repro.workloads.workload import workload_from_arrays

    wl = workload_from_arrays(
        res=[1, 1, 2, 1], subtime=[0, 0, 10, 10],
        runtime=[100, 100, 50, 15], reqtime=[120, 120, 60, 18], nb_res=2,
    )
    plat = PlatformSpec(nb_nodes=2)
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS)
    _, ok = run_pydes(plat, wl, cfg)
    _, bug = run_pydes(plat, wl, cfg, split_simultaneous_events=True)
    return {
        "atomic_starts": ok.schedule_table()[:, 0].tolist(),
        "split_bug_starts": bug.schedule_table()[:, 0].tolist(),
        "diverged": not np.array_equal(ok.schedule_table(), bug.schedule_table()),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="nasa_ipsc")
    ap.add_argument("--jobs", type=int, default=400)
    ap.add_argument("--timeouts", default="5,15,30,60")
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--fig1", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.fig1:
        print(json.dumps(fig1(), indent=2))
        return

    rows, result = sweep(
        args.preset,
        args.jobs,
        [int(t) for t in args.timeouts.split(",")],
        validate=args.validate,
    )
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    print(
        f"# {len(SCHEDULERS)}x{len(rows)//len(SCHEDULERS)} grid = "
        f"{result.n_compiles if result.n_compiles is not None else '?'} "
        f"compiled program(s), {result.wall_s:.2f}s"
    )
    if args.validate:
        worst = max(r["energy_dev"] for r in rows)
        print(f"# max energy deviation vs oracle: {worst:.2e} (paper: <= 1e-2)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
    return rows, result


if __name__ == "__main__":
    main()
