"""RL-workflow throughput (paper §4: "speed advantage is particularly
beneficial for RL workflows that require many repeated simulations").

Measures environment decision-steps/second:
  * host loop over a single HPCGymEnv (the paper's Gym cadence),
  * jitted vmapped batch of N environments (SPARS-X's fused rollout),
and the A2C update throughput (env steps consumed per second of update).
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.core.engine import init_state, make_const
from repro.core.rl.a2c import A2CConfig, TrainState, make_batched_sims, make_update_fn
from repro.core.rl.env import EnvConfig, HPCGymEnv, env_reset, env_step
from repro.core.rl.networks import policy_init
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.training.optimizer import adamw
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--jobs", type=int, default=64)
    ap.add_argument("--envs", type=int, default=256)
    ap.add_argument("--steps", type=int, default=64)
    args = ap.parse_args(argv)

    plat = PlatformSpec(nb_nodes=args.nodes)
    wl = generate_workload(GeneratorConfig(n_jobs=args.jobs, nb_res=args.nodes, seed=0))
    ecfg = EnvConfig(
        engine=EngineConfig(
            psm=PSMVariant.RL, base=BasePolicy.EASY, rl_decision_interval=600
        ),
        max_steps=args.steps * 4,
    )
    # closure constant of the jitted vmapped step -> specialized flags
    const = make_const(plat, ecfg.engine, specialize=True)

    # --- host-loop single env (paper-style Gym cadence) ---
    env = HPCGymEnv(plat, wl, ecfg)
    env.reset()
    env.step(0)  # compile
    t0 = time.perf_counter()
    n_host = 0
    env.reset()
    for i in range(args.steps):
        _, _, done, _ = env.step(i % env.action_space_n)
        n_host += 1
        if done:
            env.reset()
    t_host = time.perf_counter() - t0

    # --- vmapped batch ---
    sims0 = make_batched_sims(plat, [wl] * args.envs, ecfg)
    states, obs = jax.jit(jax.vmap(functools.partial(env_reset, ecfg, const)))(sims0)
    vstep = jax.jit(jax.vmap(functools.partial(env_step, ecfg, const)))
    actions = jnp.zeros((args.envs,), jnp.int32)
    states, obs, r, d, _ = vstep(states, actions)  # compile
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(args.steps):
        states, obs, r, d, _ = vstep(states, actions)
    jax.block_until_ready(r)
    t_vmap = time.perf_counter() - t0
    n_vmap = args.steps * args.envs

    # --- sharded vmapped batch (core/SEMANTICS.md §Device-sharded sweeps,
    # RL layer): the same jitted step over an env batch placed on the 1-D
    # device mesh — XLA partitions the elementwise batch, so each device
    # rolls out envs/D environments in parallel
    t_shard = None
    D = jax.device_count()
    if D > 1 and args.envs % D == 0:
        from repro.core.rl.env import shard_env_batch

        states_sh, _ = jax.jit(jax.vmap(functools.partial(env_reset, ecfg, const)))(
            shard_env_batch(sims0, D)
        )
        actions_sh = shard_env_batch(actions, D)
        states_sh, _, r, d, _ = vstep(states_sh, actions_sh)  # compile
        jax.block_until_ready(r)
        t0 = time.perf_counter()
        for _ in range(args.steps):
            states_sh, _, r, d, _ = vstep(states_sh, actions_sh)
        jax.block_until_ready(r)
        t_shard = time.perf_counter() - t0

    # --- A2C update throughput ---
    acfg = A2CConfig(n_envs=args.envs, n_steps=8)
    update, opt = make_update_fn(ecfg, const, sims0, acfg)
    params = policy_init(jax.random.PRNGKey(0), ecfg.obs_size, ecfg.n_actions)
    ts = TrainState(
        params, opt.init(params), states, obs, jax.random.PRNGKey(1)
    )
    update_j = jax.jit(update)
    ts, m = update_j(ts)  # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    n_upd = 4
    for _ in range(n_upd):
        ts, m = update_j(ts)
    jax.block_until_ready(m["loss"])
    t_upd = time.perf_counter() - t0
    env_steps_per_update = args.envs * acfg.n_steps

    host_rate = n_host / t_host
    vmap_rate = n_vmap / t_vmap
    print(f"host_single_env_steps_per_s={host_rate:.0f}")
    print(f"vmapped_{args.envs}env_steps_per_s={vmap_rate:.0f}")
    print(f"vmap_speedup={vmap_rate/host_rate:.1f}x")
    rates = dict(host=host_rate, vmap=vmap_rate)
    if t_shard is not None:
        shard_rate = n_vmap / t_shard
        rates["sharded"] = shard_rate
        print(f"sharded_{args.envs}env_x{D}dev_steps_per_s={shard_rate:.0f}")
    print(
        f"a2c_update_s={t_upd/n_upd:.3f} "
        f"env_steps_per_s_in_training={env_steps_per_update*n_upd/t_upd:.0f}"
    )
    return rates


if __name__ == "__main__":
    main()
