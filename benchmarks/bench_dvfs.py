"""Runtime-DVFS grid benchmark (core/SEMANTICS.md §DVFS).

A scheduler x DVFS-config grid — DVFS-enabled policy stacks crossed with
mode-table platform variants — as ONE compiled program, asserting the
one-compile guarantee holds with rule 9 in the superset. Reports wall time
and simulated jobs/s for the ``dvfs`` section of ``BENCH_grid.json``.

    PYTHONPATH=src python -m benchmarks.bench_dvfs --jobs 300
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.core import engine
from repro.core.types import EngineConfig
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import dvfs_platform_example, platform_from_groups


def scenario_grid(platform):
    """Schedulers x DVFS configs: ladder stacks, a non-DVFS baseline, and a
    mode-table platform variant (hotter turbo watts) — every point a traced
    scenario."""
    hot = platform_from_groups(
        tuple(
            dataclasses.replace(
                g,
                dvfs_modes=tuple(
                    dataclasses.replace(m, power=1.25 * m.power)
                    for m in g.dvfs_modes
                ),
            )
            for g in platform.groups()
        )
    )
    labels = ("EASY PSUS", "EASY DVFS", "FCFS DVFS", "EASY PSUS+DVFS",
              "EASY PSAS+IPM+DVFS")
    grid = [{"scheduler": lbl, "timeout": 900} for lbl in labels]
    grid += [
        {"scheduler": "EASY DVFS", "timeout": 900, "platform": hot},
        {"scheduler": "EASY DVFS", "timeout": 300},
    ]
    return grid


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=48)
    args = ap.parse_args(argv)

    plat = dvfs_platform_example(args.nodes)
    wl = generate_workload(
        GeneratorConfig(n_jobs=args.jobs, nb_res=args.nodes, seed=7)
    )
    cfg = EngineConfig(node_order="cheap", terminate_overrun=True)
    grid = scenario_grid(plat)

    engine.sweep(plat, wl, grid, cfg)  # warm-up: compile once
    t0 = time.perf_counter()
    batch = engine.sweep(plat, wl, grid, cfg)
    wall = time.perf_counter() - t0
    assert batch.n_compiles in (None, 1), (
        f"the DVFS grid recompiled: {batch.n_compiles} programs"
    )

    rows = []
    for sc, m in zip(grid, batch.metrics):
        rows.append(
            {
                "scheduler": sc["scheduler"],
                "timeout": sc["timeout"],
                "platform": "hot" if "platform" in sc else "base",
                "total_energy_kwh": round(m.total_energy_j / 3.6e6, 3),
                "mean_wait_s": round(m.mean_wait_s, 1),
                # residency across >1 mode proves rule 9 actually switched
                "modes_used": int(
                    sum(sum(1 for r in g if r > 0) for g in m.mode_residency_s)
                ),
            }
        )
    out = {
        "n_compiles": batch.n_compiles,
        "grid_k": len(grid),
        "wall_s": round(wall, 3),
        "jobs_per_s": round(len(grid) * args.jobs / wall, 1) if wall else None,
        "rows": rows,
    }
    print(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    main()
