"""Curie-scale SWF trace replay benchmark (paper §3.1 + ROADMAP items 1-2).

Replays a 10k-job Curie-class SWF trace (synthesized offline with the
``cea_curie`` preset statistics; the real ``CEA-Curie-2011-2.1-cln.swf``
drops into the same path when present) on the 11 200-node 3-group
:func:`~repro.workloads.platform.curie_platform`, through the streaming
reader and replay adaptation in :mod:`repro.workloads.traces`.

Two phases:

* **verify** — grouped-tables == dense bit-exact per scheduler label on a
  scaled-down Curie platform (same 3-group structure), plus the same
  assert at full scale for the timed config. Schedule fields must match
  exactly; energy to f32 rounding (occ · power contraction vs per-node
  scatter-add reduce in different orders).
* **bench** — single-run wall time, grouped vs dense fused, on the full
  11 200-node platform (the regime where ``BENCH_grid.json``'s
  ``scale.single_run_fused_s`` baseline was recorded). The grouped run is
  the O(N) → O(G) payoff: per-batch energy/event reductions over G = 3
  groups and a sort-free hoisted allocation order instead of two O(N log N)
  argsorts per attempt.

``--full`` additionally times the complete 10k-job replay on the grouped
path (minutes of wall time; the quick mode replays the trace head).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from repro.core import engine
from repro.core.policy import from_label, scheduler_labels
from repro.core.types import EngineConfig
from repro.workloads.platform import curie_platform
from repro.workloads.traces import replay_workload, synthesize_curie_swf
from repro.workloads.workload import Workload

# schedule fields that must be bit-exact between the grouped and dense
# paths (energy is compared separately, to rounding)
EXACT_FIELDS = (
    "job_status", "job_start", "job_finish", "t", "n_batches", "n_allocs",
)


def assert_grouped_matches_dense(s_grp, s_dense, where: str) -> None:
    for f in EXACT_FIELDS:
        a, b = getattr(s_grp, f, None), getattr(s_dense, f, None)
        if a is None or b is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"grouped != dense on {f!r} ({where})",
        )
    np.testing.assert_allclose(
        np.asarray(s_grp.energy), np.asarray(s_dense.energy),
        rtol=1e-6, err_msg=f"grouped energy drifted past rounding ({where})",
    )


def _timed_single(plat, wl: Workload, cfg: EngineConfig) -> tuple:
    """(wall seconds of the cached program, final state): warm-up compile
    first, then one timed run."""
    out = engine.simulate(plat, wl, cfg)
    jax.block_until_ready(out.energy)
    t0 = time.perf_counter()
    out = engine.simulate(plat, wl, cfg)
    jax.block_until_ready(out.energy)
    return time.perf_counter() - t0, out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=10_000,
                    help="synthesized trace length (SWF lines)")
    ap.add_argument("--nodes", type=int, default=11_200)
    ap.add_argument("--bench-jobs", type=int, default=200,
                    help="trace-head jobs for the timed full-scale runs "
                         "(matches the regime of BENCH_grid.json's "
                         "scale.single_run_fused_s baseline)")
    ap.add_argument("--verify-jobs", type=int, default=120)
    ap.add_argument("--verify-nodes", type=int, default=280,
                    help="scaled-down Curie platform for the per-label "
                         "grouped==dense sweep (same 3-group structure)")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--swf", default=None,
                    help="existing SWF trace to replay (default: synthesize "
                         "a Curie-class trace)")
    ap.add_argument("--full", action="store_true",
                    help="also time the complete trace replay (grouped)")
    args = ap.parse_args(argv)

    # --- trace: synthesize (offline container) or replay a provided file ---
    tmp = None
    swf = args.swf
    if swf is None:
        tmp = tempfile.mkdtemp(prefix="bench_curie_")
        swf = synthesize_curie_swf(
            os.path.join(tmp, "curie.swf"), n_jobs=args.jobs
        )
    wl_full = replay_workload(swf, nb_nodes=args.nodes, oversize="clamp")
    print(f"trace: {len(wl_full)} jobs on {args.nodes} nodes "
          f"(max req {max(j.res for j in wl_full.jobs)} nodes) "
          f"[{os.path.basename(swf)}]")

    # --- verify: grouped == dense per label, scaled-down Curie platform ---
    plat_v = curie_platform(args.verify_nodes)
    wl_v = replay_workload(
        swf, nb_nodes=args.verify_nodes, oversize="clamp",
        max_jobs=args.verify_jobs,
    )
    labels = scheduler_labels()
    for label in labels:
        base, pol = from_label(label)
        cfg = EngineConfig(
            base=base, policy=pol, timeout=args.timeout, node_order="cheap"
        )
        s_dense = engine.simulate(plat_v, wl_v, cfg)
        s_grp = engine.simulate(
            plat_v, wl_v, dataclasses.replace(cfg, grouped_tables=True)
        )
        assert_grouped_matches_dense(
            s_grp, s_dense, f"{label}, {args.verify_nodes} nodes"
        )
    print(f"verify: grouped == dense bit-exact for {len(labels)} labels "
          f"x {args.verify_jobs} replayed jobs on {args.verify_nodes} nodes")

    # --- bench: full-scale single runs on the trace head ---
    wl_b = replay_workload(
        swf, nb_nodes=args.nodes, oversize="clamp", max_jobs=args.bench_jobs
    )
    plat = curie_platform(args.nodes)
    base, pol = from_label("EASY PSUS")
    cfg_dense = EngineConfig(
        base=base, policy=pol, timeout=args.timeout, fused_events=True
    )
    cfg_grp = dataclasses.replace(cfg_dense, grouped_tables=True)
    cfg_grp_merge = dataclasses.replace(cfg_grp, merge_bursts=True)

    t_dense, out_dense = _timed_single(plat, wl_b, cfg_dense)
    t_grouped, out_grp = _timed_single(plat, wl_b, cfg_grp)
    # the full-scale twin of the verify sweep — the timed programs
    # themselves must agree before their times mean anything
    assert_grouped_matches_dense(
        out_grp, out_dense, f"EASY PSUS, {args.nodes} nodes"
    )
    t_merge, out_merge = _timed_single(plat, wl_b, cfg_grp_merge)

    print(f"single_run_dense_fused_s={t_dense:.2f} "
          f"(batches={int(out_dense.n_batches)})")
    print(f"single_run_grouped_s={t_grouped:.2f} "
          f"({t_dense / t_grouped:.1f}x vs dense fused)")
    print(f"single_run_grouped_merge_s={t_merge:.2f} "
          f"(merge_bursts on; batches={int(out_merge.n_batches)})")

    result = dict(
        trace_jobs=len(wl_full), bench_jobs=len(wl_b), nodes=args.nodes,
        n_groups=plat.n_groups(), verify_labels=len(labels),
        t_dense_fused=t_dense, t_grouped=t_grouped, t_grouped_merge=t_merge,
    )

    if args.full:
        t_all, out_all = _timed_single(plat, wl_full, cfg_grp)
        print(f"full_replay_grouped_s={t_all:.2f} "
              f"({len(wl_full)} jobs, batches={int(out_all.n_batches)}, "
              f"{len(wl_full) / t_all:.0f} jobs/s)")
        result["t_full_replay_grouped"] = t_all
        result["full_replay_jobs"] = len(wl_full)
    return result


if __name__ == "__main__":
    main()
