"""Compatibility shim: the docs checker now lives in tools/lint/docs_pass.py
as the SL007 pass of spars-lint (`make lint`). This entry point — and
`make docs-check` — keep working for scripts and muscle memory.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "lint")
)

from docs_pass import DOCS, REPO, check_doc, collect, main  # noqa: E402,F401

if __name__ == "__main__":
    sys.exit(1 if main() else 0)
