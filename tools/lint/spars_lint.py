#!/usr/bin/env python
"""spars-lint — repo-invariant static analysis for the SPARS reproduction.

The engine's reproducibility guarantees rest on hand-maintained invariants
(core/SEMANTICS.md §Design rules): every static ``EngineConfig`` field read
inside a jitted body must ride the jit-cache key, every ``PolicyParams``
flag must be branched on through ``static_bool``, every engine rule needs a
bit-exact pydes oracle twin, every Pallas wrapper needs a reference
fallback, and jit-traced bodies must stay pure. Two shipped bugs (the PR 5
rebuild-every-call recompile and the PR 6 cache-key-distinctness fix) were
exactly these invariants drifting; this tool machine-checks them as AST
passes so the next flag/const/kernel cannot break them silently.

Passes (each emits ``file:line RULE message``):

* **SL001 trace-key completeness** — every ``cfg.<attr>`` read inside the
  functions reachable from ``run_sim``/``run_sim_gantt`` (i.e. trace
  structure of the jitted program) appears in ``_static_trace_key``. A
  missed field silently reuses a program compiled for a different config
  (cache collision) or recompiles per call.
* **SL002 flag-gate discipline** — no raw ``pp.<flag>`` read of a
  ``PolicyParams`` field in a Python boolean context (``if``/``while``/
  ``assert``/``and``/``or``/``not``/ternary) in engine.py or policy.py:
  all must route through ``static_bool`` so the traced superset and the
  specialized DCE path stay the same program (§Static specialization).
* **SL003 oracle-twin coverage** — engine rule functions (first parameter
  ``s``) must map to a ``PyDES`` method by naming convention (modulo the
  documented alias and one-sided-by-design tables), and vice versa, so the
  two engines cannot drift one-sidedly.
* **SL004 kernel-contract** — every Pallas wrapper in ``kernels/ops.py``
  (a function calling a ``_*_kernel`` import) has a ``*_reference`` twin
  in ``kernels/ref.py``, a zero-size short-circuit, and a conditional
  untileable-fallback route to the reference.
* **SL005 tracer-leak / purity** — no ``np.``/``print``/``warnings`` host
  calls and no ``bool()``/``int()``/``float()``/``.item()`` coercion of
  traced values (``s.*`` / ``const.*``) inside jit-traced bodies.
* **SL006 metrics-row consistency** — every ``SimMetrics`` field is
  consumed by ``row()`` (transitively through its helper methods), so a
  gated field cannot ship without its gated column.
* **SL007 docs hygiene** — the former ``tools/docs_check.py``
  (``docs_pass.py``): dead links, stale file refs, fence balance, fenced
  command resolution.

Waive an intentional violation with ``# spars-lint: ignore[SLxxx] <reason>``
on the flagged line, or anywhere in the contiguous comment block directly
above it. Run as ``make lint`` (all passes), ``make docs-check``
(``--only SL007``), or in tier-1 via ``tests/test_lint.py``.
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Set, Tuple

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import docs_pass  # noqa: E402

REPO = docs_pass.REPO

# repo-relative locations of the checked files; a fixture tree (tests/
# fixtures/lint/<case>/) overrides the root and provides only the files its
# rule needs — a pass whose files are absent is skipped for that root
ENGINE = "src/repro/core/engine.py"
POLICY = "src/repro/core/policy.py"
PYDES = "src/repro/core/ref/pydes.py"
TYPES = "src/repro/core/types.py"
OPS = "src/repro/kernels/ops.py"
KREF = "src/repro/kernels/ref.py"


class Finding(NamedTuple):
    file: str  # root-relative path
    line: int
    rule: str
    msg: str

    def render(self) -> str:
        return f"{self.file}:{self.line} {self.rule} {self.msg}"


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------

_IGNORE = re.compile(r"#\s*spars-lint:\s*ignore\[([A-Z0-9, ]+)\]")
_COMMENT_ONLY = re.compile(r"^\s*#")


class _File:
    """Parsed source + waiver lookup for one file."""

    def __init__(self, root: str, rel: str):
        self.rel = rel
        self.path = os.path.join(root, rel)
        with open(self.path) as f:
            self.source = f.read()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=rel)

    def waived(self, line: int, rule: str) -> bool:
        """True if ``line`` (1-based) or the contiguous comment block
        directly above it carries ``# spars-lint: ignore[rule]``."""
        i = line - 1
        if 0 <= i < len(self.lines) and self._tagged(self.lines[i], rule):
            return True
        i -= 1
        while i >= 0 and _COMMENT_ONLY.match(self.lines[i]):
            if self._tagged(self.lines[i], rule):
                return True
            i -= 1
        return False

    @staticmethod
    def _tagged(text: str, rule: str) -> bool:
        m = _IGNORE.search(text)
        return bool(m) and rule in [r.strip() for r in m.group(1).split(",")]


def _load(root: str, rel: str) -> Optional[_File]:
    if not os.path.exists(os.path.join(root, rel)):
        return None
    return _File(root, rel)


def _top_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {
        n.name: n
        for n in tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def _class_methods(tree: ast.Module, cls: str) -> Dict[str, ast.FunctionDef]:
    for n in tree.body:
        if isinstance(n, ast.ClassDef) and n.name == cls:
            return {
                m.name: m
                for m in n.body
                if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
    return {}


def _called_names(node: ast.AST) -> Set[str]:
    """Names invoked as plain calls anywhere under ``node`` (incl. nested
    defs/lambdas — lax.while_loop bodies are nested functions)."""
    return {
        n.func.id
        for n in ast.walk(node)
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
    }


def _mentions(node: ast.AST, names: Set[str]) -> bool:
    return any(
        isinstance(n, ast.Name) and n.id in names for n in ast.walk(node)
    )


def _attr_names(node: ast.AST) -> Set[str]:
    return {n.attr for n in ast.walk(node) if isinstance(n, ast.Attribute)}


_CFG_NAMES = {"cfg", "config"}


def _cfg_reads(fn: ast.AST) -> List[Tuple[str, int]]:
    """Dotted config-attribute paths read under ``fn``.

    ``cfg.window`` -> ``window``; ``cfg.policy.dvfs`` and
    ``getattr(cfg.policy, "dvfs", ...)`` -> ``policy.dvfs`` (the bare
    ``policy`` base is consumed by the compound read).
    """
    reads: List[Tuple[str, int]] = []
    consumed: Set[int] = set()

    def is_cfg_attr(n: ast.AST) -> bool:
        return (
            isinstance(n, ast.Attribute)
            and isinstance(n.value, ast.Name)
            and n.value.id in _CFG_NAMES
        )

    for n in ast.walk(fn):
        # getattr(cfg.X, "Y", ...) -> "X.Y"
        if (
            isinstance(n, ast.Call)
            and isinstance(n.func, ast.Name)
            and n.func.id == "getattr"
            and n.args
            and is_cfg_attr(n.args[0])
            and len(n.args) >= 2
            and isinstance(n.args[1], ast.Constant)
            and isinstance(n.args[1].value, str)
        ):
            reads.append(
                (f"{n.args[0].attr}.{n.args[1].value}", n.lineno)
            )
            consumed.add(id(n.args[0]))
        # cfg.X.Y -> "X.Y"
        elif isinstance(n, ast.Attribute) and is_cfg_attr(n.value):
            reads.append((f"{n.value.attr}.{n.attr}", n.lineno))
            consumed.add(id(n.value))
    for n in ast.walk(fn):
        if is_cfg_attr(n) and id(n) not in consumed:
            reads.append((n.attr, n.lineno))
    return reads


def _cfg_call_args(fn: ast.AST) -> Set[str]:
    """Module-level function names that ``fn`` calls with the config object
    as an argument (``_fused_kernel_on(config)`` — their own cfg reads are
    part of the caller's trace structure)."""
    out: Set[str] = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name):
            for a in n.args:
                if isinstance(a, ast.Name) and a.id in _CFG_NAMES:
                    out.add(n.func.id)
    return out


def _reachable(
    funcs: Dict[str, ast.FunctionDef], roots: Iterable[str]
) -> Set[str]:
    seen: Set[str] = set()
    todo = [r for r in roots if r in funcs]
    while todo:
        name = todo.pop()
        if name in seen:
            continue
        seen.add(name)
        todo.extend(c for c in _called_names(funcs[name]) if c in funcs)
    return seen


# ---------------------------------------------------------------------------
# SL001 — trace-key completeness
# ---------------------------------------------------------------------------

TRACE_ROOTS = ("run_sim", "run_sim_gantt")
KEY_FN = "_static_trace_key"


def check_sl001(root: str) -> List[Finding]:
    f = _load(root, ENGINE)
    if f is None:
        return []
    funcs = _top_functions(f.tree)
    key_fn = funcs.get(KEY_FN)
    if key_fn is None:
        return [
            Finding(f.rel, 1, "SL001",
                    f"jit cache key function {KEY_FN}() not found")
        ]
    covered = {p for p, _ in _cfg_reads(key_fn)}
    # a helper called with the config object inside the key contributes its
    # own static reads to the key (e.g. _fused_kernel_on(config))
    for helper in _cfg_call_args(key_fn):
        if helper in funcs:
            covered |= {p for p, _ in _cfg_reads(funcs[helper])}

    out: List[Finding] = []
    for name in sorted(_reachable(funcs, TRACE_ROOTS)):
        for path, line in _cfg_reads(funcs[name]):
            if path in covered:
                continue
            # a compound read (policy.controller) also covers checks that
            # re-read its exact dotted path; a bare base read is only
            # covered by a bare entry
            if f.waived(line, "SL001"):
                continue
            out.append(Finding(
                f.rel, line, "SL001",
                f"static config read `cfg.{path}` in jitted scope "
                f"({name}) is missing from {KEY_FN} — cache collisions "
                "or per-call recompiles",
            ))
    return out


# ---------------------------------------------------------------------------
# SL002 — flag-gate discipline
# ---------------------------------------------------------------------------

# fallback when the checked tree does not carry policy.py (fixture roots);
# the live run parses PolicyParams so new flags are picked up automatically
DEFAULT_FLAGS = (
    "backfill", "eager_ready", "sleep_enabled", "ipm_enabled",
    "rl_enabled", "rl_grouped", "dvfs_enabled", "dvfs_rl",
    "forecast_enabled", "forecast_dvfs",
)

STATIC_ACCESSOR = "static_bool"


def _policy_flags(root: str) -> Tuple[str, ...]:
    f = _load(root, POLICY)
    if f is None:
        return DEFAULT_FLAGS
    for n in f.tree.body:
        if isinstance(n, ast.ClassDef) and n.name == "PolicyParams":
            fields = tuple(
                stmt.target.id
                for stmt in n.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            )
            if fields:
                return fields
    return DEFAULT_FLAGS


def _gate_exprs(tree: ast.AST) -> List[ast.AST]:
    """Expressions evaluated in a Python boolean context."""
    out: List[ast.AST] = []
    for n in ast.walk(tree):
        if isinstance(n, (ast.If, ast.While, ast.IfExp)):
            out.append(n.test)
        elif isinstance(n, ast.Assert):
            out.append(n.test)
        elif isinstance(n, ast.BoolOp):
            out.extend(n.values)
        elif isinstance(n, ast.UnaryOp) and isinstance(n.op, ast.Not):
            out.append(n.operand)
    return out


def _raw_flag_reads(
    expr: ast.AST, flags: Set[str]
) -> List[ast.Attribute]:
    """Flag attribute reads under ``expr`` not wrapped in static_bool()."""
    hits: List[ast.Attribute] = []

    def visit(node: ast.AST, shielded: bool) -> None:
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == STATIC_ACCESSOR
        ):
            shielded = True
        if (
            isinstance(node, ast.Attribute)
            and node.attr in flags
            and not shielded
        ):
            hits.append(node)
        for child in ast.iter_child_nodes(node):
            visit(child, shielded)

    visit(expr, False)
    return hits


def check_sl002(root: str) -> List[Finding]:
    flags = set(_policy_flags(root))
    out: List[Finding] = []
    for rel in (ENGINE, POLICY):
        f = _load(root, rel)
        if f is None:
            continue
        for expr in _gate_exprs(f.tree):
            for hit in _raw_flag_reads(expr, flags):
                if f.waived(hit.lineno, "SL002"):
                    continue
                out.append(Finding(
                    f.rel, hit.lineno, "SL002",
                    f"raw PolicyParams flag `.{hit.attr}` in a Python "
                    f"boolean gate — route through {STATIC_ACCESSOR}() so "
                    "traced sweeps and specialized DCE stay one program",
                ))
    return out


# ---------------------------------------------------------------------------
# SL003 — oracle-twin coverage
# ---------------------------------------------------------------------------

# engine rule name -> PyDES method name, where the convention (strip
# leading underscores, equal names) does not hold for historical reasons
SL003_ALIASES = {
    "_complete_jobs": "_complete",
    "_complete_transitions": "_transitions",
    "_ready_times": "_ready",
    "accrue_energy": "_accrue",
    "apply_rl_commands": "_apply_rl",
    "run_sim": "run",
}

# engine-side rule functions with no oracle twin BY DESIGN (vectorization
# artifacts of rules that are twinned at a coarser granularity); every
# entry names its justification so additions are a conscious act
SL003_ENGINE_ONLY = {
    "_queue_window": "window scatter spelling of _scheduler_pass's queue slice",
    "_sched_attempt": "loop-body factoring shared by both scheduler loops",
    "_power_step": "rules 6-9 dispatcher; the oracle inlines it in _process_batch",
    "_time_candidates": "folded into the oracle's _next_time",
    "_next_transition": "folded into the oracle's _next_time",
    "_node_power_draw": "inlined in the oracle's _accrue",
    "event_horizon": "fused next_time+draw spelling (§Hot loop); parity-tested",
    "_quiet_batch": "proven-no-op fast path; the oracle has no quiet dispatch",
    "all_done": "inlined in the oracle's run loop",
    "run_sim_gantt": "gantt-recording variant of run_sim",
}

# oracle-side methods with no s-first engine twin BY DESIGN
SL003_ORACLE_ONLY = {
    "__init__": "constructor",
    "_partition_select": "host spelling of the engine's _partition_pick "
                         "per-group masked cumsum inside _try_allocate",
    "energy_by_state": "legacy view summed from energy_by_group",
    "_eff_speed": "twin is policy.effective_node_speed (const-first signature)",
    "_sort_key": "host spelling of the engine's (ready, order_key, nid) argsort",
    "_gantt_mark": "oracle-side gantt recorder; engine twin is run_sim_gantt's log",
    "_eligible": "inlined in the engine as the `node_job < 0` mask",
    "metrics": "engine twin is metrics.metrics_from_state (host-side module)",
    "schedule_table": "engine twin is metrics.schedule_table (host-side module)",
}


def _norm(name: str) -> str:
    return name.lstrip("_")


def check_sl003(root: str) -> List[Finding]:
    pydes = _load(root, PYDES)
    engine = _load(root, ENGINE)
    if pydes is None or engine is None:
        return []
    oracle = _class_methods(pydes.tree, "PyDES")
    candidates: List[Tuple[_File, ast.FunctionDef]] = []
    for rel in (ENGINE, POLICY):
        f = _load(root, rel)
        if f is None:
            continue
        for fn in _top_functions(f.tree).values():
            args = fn.args.args
            if args and args[0].arg == "s":
                candidates.append((f, fn))

    out: List[Finding] = []
    engine_targets: Set[str] = set()
    for f, fn in candidates:
        target = SL003_ALIASES.get(fn.name, fn.name)
        engine_targets.add(_norm(target))
        if fn.name in SL003_ENGINE_ONLY:
            continue
        if any(_norm(m) == _norm(target) for m in oracle):
            continue
        if f.waived(fn.lineno, "SL003"):
            continue
        out.append(Finding(
            f.rel, fn.lineno, "SL003",
            f"engine rule `{fn.name}` has no pydes oracle twin "
            f"(expected PyDES.{target} or an alias/engine-only entry in "
            "spars_lint.SL003_*) — engine/oracle drift",
        ))
    for name, m in oracle.items():
        if name in SL003_ORACLE_ONLY or _norm(name) in engine_targets:
            continue
        if pydes.waived(m.lineno, "SL003"):
            continue
        out.append(Finding(
            pydes.rel, m.lineno, "SL003",
            f"oracle method `PyDES.{name}` has no engine rule twin "
            "(expected a matching s-first function or an alias/oracle-only "
            "entry in spars_lint.SL003_*) — engine/oracle drift",
        ))
    return out


# ---------------------------------------------------------------------------
# SL004 — Pallas kernel-wrapper contract
# ---------------------------------------------------------------------------

_KERNEL_NAME = re.compile(r"^_\w*kernel$")


def _ref_calls(fn: ast.FunctionDef) -> List[ast.Call]:
    """Calls to ``ref.<x>_reference`` under ``fn``."""
    return [
        n
        for n in ast.walk(fn)
        if isinstance(n, ast.Call)
        and isinstance(n.func, ast.Attribute)
        and isinstance(n.func.value, ast.Name)
        and n.func.value.id == "ref"
        and n.func.attr.endswith("_reference")
    ]


def _has_zero_size_guard(fn: ast.FunctionDef) -> bool:
    """An If whose test compares against 0 (``e == 0`` / ``0 in shape``)
    and whose body returns — the zero-size short-circuit."""
    for n in ast.walk(fn):
        if not isinstance(n, ast.If):
            continue
        zeroish = any(
            isinstance(c, ast.Compare)
            and any(isinstance(op, (ast.Eq, ast.In)) for op in c.ops)
            and any(
                isinstance(x, ast.Constant) and x.value == 0
                for x in [c.left] + list(c.comparators)
            )
            for c in ast.walk(n.test)
        )
        if zeroish and any(
            isinstance(b, ast.Return) for b in ast.walk(n)
        ):
            return True
    return False


def _conditional_ref_route(fn: ast.FunctionDef) -> bool:
    """At least one ref.*_reference call lives under an If (the
    untileable-shape fallback), not as the unconditional body."""
    for n in ast.walk(fn):
        if isinstance(n, ast.If):
            if any(_ref_calls_in(n)):
                return True
    return False


def _ref_calls_in(node: ast.AST) -> List[ast.Call]:
    return [
        c
        for c in ast.walk(node)
        if isinstance(c, ast.Call)
        and isinstance(c.func, ast.Attribute)
        and isinstance(c.func.value, ast.Name)
        and c.func.value.id == "ref"
        and c.func.attr.endswith("_reference")
    ]


def check_sl004(root: str) -> List[Finding]:
    ops = _load(root, OPS)
    if ops is None:
        return []
    kref = _load(root, KREF)
    ref_defs = set(_top_functions(kref.tree)) if kref else set()

    out: List[Finding] = []
    for fn in _top_functions(ops.tree).values():
        calls_kernel = any(
            _KERNEL_NAME.match(c) for c in _called_names(fn)
        )
        if not calls_kernel:
            continue
        waived = ops.waived(fn.lineno, "SL004")
        refs = _ref_calls(fn)
        if not refs:
            if not waived:
                out.append(Finding(
                    ops.rel, fn.lineno, "SL004",
                    f"kernel wrapper `{fn.name}` never routes to a "
                    "ref.*_reference twin — untileable shapes have no "
                    "fallback",
                ))
        else:
            for call in refs:
                if kref is not None and call.func.attr not in ref_defs:
                    out.append(Finding(
                        ops.rel, call.lineno, "SL004",
                        f"kernel wrapper `{fn.name}` falls back to "
                        f"ref.{call.func.attr}, which does not exist in "
                        f"{KREF}",
                    ))
            if not _conditional_ref_route(fn) and not waived:
                out.append(Finding(
                    ops.rel, fn.lineno, "SL004",
                    f"kernel wrapper `{fn.name}`'s reference route is "
                    "unconditional — the kernel path is dead",
                ))
        if not _has_zero_size_guard(fn) and not waived:
            out.append(Finding(
                ops.rel, fn.lineno, "SL004",
                f"kernel wrapper `{fn.name}` has no zero-size "
                "short-circuit (`== 0` / `0 in shape` guard returning "
                "early) — empty operands reach the kernel/reference",
            ))
    return out


# ---------------------------------------------------------------------------
# SL005 — tracer-leak / purity of jit-traced bodies
# ---------------------------------------------------------------------------

_TRACED_VARS = {"s", "const", "state"}
_HOST_COERCIONS = {"bool", "int", "float"}
_HOST_METHODS = {"item", "tolist"}


def _traced_scope(root: str) -> List[Tuple[_File, ast.FunctionDef]]:
    """The jit-traced function set: engine functions reachable from the run
    drivers, plus the s-first rule functions of policy.py."""
    out: List[Tuple[_File, ast.FunctionDef]] = []
    engine = _load(root, ENGINE)
    if engine is not None:
        funcs = _top_functions(engine.tree)
        for name in sorted(_reachable(funcs, TRACE_ROOTS)):
            out.append((engine, funcs[name]))
    policy = _load(root, POLICY)
    if policy is not None:
        for fn in _top_functions(policy.tree).values():
            if fn.args.args and fn.args.args[0].arg == "s":
                out.append((policy, fn))
    return out


def check_sl005(root: str) -> List[Finding]:
    out: List[Finding] = []
    for f, fn in _traced_scope(root):
        for n in ast.walk(fn):
            finding = None
            if isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name):
                if n.value.id == "np":
                    finding = (
                        f"host numpy call `np.{n.attr}` inside jit-traced "
                        f"body `{fn.name}` — use jnp (np breaks tracing "
                        "and silently constant-folds)"
                    )
                elif n.value.id == "warnings":
                    finding = (
                        f"host side effect `warnings.{n.attr}` inside "
                        f"jit-traced body `{fn.name}` — warn from the "
                        "host driver instead"
                    )
            elif isinstance(n, ast.Call) and isinstance(n.func, ast.Name):
                if n.func.id == "print":
                    finding = (
                        f"print() inside jit-traced body `{fn.name}` — "
                        "use jax.debug.print or log from the host"
                    )
                elif (
                    n.func.id in _HOST_COERCIONS
                    and n.args
                    and _mentions(n.args[0], _TRACED_VARS)
                    and "shape" not in _attr_names(n.args[0])
                ):
                    finding = (
                        f"{n.func.id}() on a traced value inside "
                        f"`{fn.name}` — a Python coercion of a tracer "
                        "raises ConcretizationTypeError (or silently "
                        "freezes the value at trace time)"
                    )
            elif (
                isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr in _HOST_METHODS
            ):
                finding = (
                    f".{n.func.attr}() inside jit-traced body "
                    f"`{fn.name}` — host materialization of a traced value"
                )
            if finding is None:
                continue
            if f.waived(n.lineno, "SL005"):
                continue
            out.append(Finding(f.rel, n.lineno, "SL005", finding))
    return out


# ---------------------------------------------------------------------------
# SL006 — SimMetrics field / row() column consistency
# ---------------------------------------------------------------------------

METRICS_CLASS = "SimMetrics"
ROW_FN = "row"


def check_sl006(root: str) -> List[Finding]:
    f = _load(root, TYPES)
    if f is None:
        return []
    cls = next(
        (
            n
            for n in f.tree.body
            if isinstance(n, ast.ClassDef) and n.name == METRICS_CLASS
        ),
        None,
    )
    if cls is None:
        return []
    fields = [
        (stmt.target.id, stmt.lineno)
        for stmt in cls.body
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name)
    ]
    methods = {
        m.name: m
        for m in cls.body
        if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    if ROW_FN not in methods:
        return [
            Finding(f.rel, cls.lineno, "SL006",
                    f"{METRICS_CLASS} has no {ROW_FN}() method")
        ]

    # self.<attr> reads in row(), transitively through self.method() calls
    used: Set[str] = set()
    seen: Set[str] = set()
    todo = [ROW_FN]
    while todo:
        name = todo.pop()
        if name in seen or name not in methods:
            continue
        seen.add(name)
        for n in ast.walk(methods[name]):
            if (
                isinstance(n, ast.Attribute)
                and isinstance(n.value, ast.Name)
                and n.value.id == "self"
            ):
                used.add(n.attr)
                if n.attr in methods:
                    todo.append(n.attr)

    out: List[Finding] = []
    for name, line in fields:
        if name in used or f.waived(line, "SL006"):
            continue
        out.append(Finding(
            f.rel, line, "SL006",
            f"{METRICS_CLASS} field `{name}` never reaches {ROW_FN}() — "
            "a gated metric without its gated column (or dead weight)",
        ))
    return out


# ---------------------------------------------------------------------------
# SL007 — docs hygiene (tools/lint/docs_pass.py)
# ---------------------------------------------------------------------------

def check_sl007(root: str) -> List[Finding]:
    out: List[Finding] = []
    for problem in docs_pass.collect(root=root):
        doc, _, msg = problem.partition(": ")
        out.append(Finding(doc, 1, "SL007", msg or problem))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

PASSES = (
    ("SL001", "trace-key completeness", check_sl001),
    ("SL002", "flag-gate discipline", check_sl002),
    ("SL003", "oracle-twin coverage", check_sl003),
    ("SL004", "kernel-wrapper contract", check_sl004),
    ("SL005", "tracer-leak / purity", check_sl005),
    ("SL006", "metrics-row consistency", check_sl006),
    ("SL007", "docs hygiene", check_sl007),
)

RULE_IDS = tuple(rule for rule, _, _ in PASSES)


def run_passes(
    root: str = REPO, only: Optional[Sequence[str]] = None
) -> List[Finding]:
    selected = set(only) if only else set(RULE_IDS)
    unknown = selected - set(RULE_IDS)
    if unknown:
        raise SystemExit(
            f"spars-lint: unknown rule(s) {sorted(unknown)}; "
            f"known: {', '.join(RULE_IDS)}"
        )
    findings: List[Finding] = []
    for rule, _, fn in PASSES:
        if rule in selected:
            findings.extend(fn(root))
    return sorted(findings)


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="spars-lint",
        description="repo-invariant static analysis (SL001-SL007)",
    )
    p.add_argument(
        "--root", default=REPO,
        help="tree to check (default: this repo; tests point it at "
        "seeded-violation fixtures)",
    )
    p.add_argument(
        "--only", default=None,
        help="comma-separated rule ids to run (e.g. SL001,SL004); "
        "default: all",
    )
    p.add_argument(
        "--list", action="store_true", help="list rules and exit"
    )
    args = p.parse_args(argv)
    if args.list:
        for rule, title, _ in PASSES:
            print(f"{rule}  {title}")
        return 0
    only = args.only.split(",") if args.only else None
    findings = run_passes(root=os.path.abspath(args.root), only=only)
    for x in findings:
        print(x.render(), file=sys.stderr)
    n_rules = len(only) if only else len(PASSES)
    if findings:
        print(
            f"spars-lint: {len(findings)} finding(s) "
            f"(waive intentional ones with `# spars-lint: ignore[SLxxx] "
            "<reason>`)",
            file=sys.stderr,
        )
        return 1
    print(f"spars-lint: {n_rules} pass(es) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
