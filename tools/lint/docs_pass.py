"""SL007 — documentation hygiene (the former ``tools/docs_check.py``).

Scans the repo's markdown docs for

1. unbalanced triple-backtick code fences,
2. relative markdown links whose target file does not exist
   (``[text](path)``; http(s)/mailto/anchor links are skipped),
3. backtick-quoted repo paths that no longer exist (e.g. a doc naming
   ``src/repro/core/policy.py`` after a rename),
4. runnable command lines inside ``sh`` fences whose entry point is gone:
   ``python -m <module>`` must resolve to a file under ``src/`` or the repo
   root, ``python <path>.py`` must exist.

Runs as one pass of the ``spars-lint`` driver (``make lint``); the legacy
entry points — ``make docs-check``, ``python tools/docs_check.py``, and the
tier-1 wrapper ``tests/test_docs.py`` — all route here.
"""
from __future__ import annotations

import os
import re
import sys
from typing import List

REPO = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)

DOCS = (
    "README.md",
    "ROADMAP.md",
    "src/repro/core/SEMANTICS.md",
    "src/repro/experiments/README.md",
    "tests/README.md",
)

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_PATH = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|json|yml|yaml))`")
_PY_MODULE = re.compile(r"python\s+-m\s+([A-Za-z0-9_.]+)")
_PY_FILE = re.compile(r"python\s+([A-Za-z0-9_./-]+\.py)")


def _exists(path: str, doc_dir: str, root: str) -> bool:
    """A referenced path may be doc-relative, repo-root-relative, or the
    repo's `core/...`-style shorthand rooted at src/repro."""
    bases = (doc_dir, root, os.path.join(root, "src"),
             os.path.join(root, "src", "repro"))
    return any(os.path.exists(os.path.join(b, path)) for b in bases)


def _local_package(module: str, root: str) -> bool:
    """Only repo-local packages are checkable (pytest etc. are not)."""
    top = module.split(".", 1)[0]
    return any(
        os.path.exists(os.path.join(root, r, top)) for r in ("src", ".")
    )


def _module_file(module: str, root: str) -> bool:
    rel = module.replace(".", "/")
    return any(
        os.path.exists(os.path.join(root, r, p))
        for r in ("src", ".")
        for p in (f"{rel}.py", f"{rel}/__init__.py")
    )


def check_doc(path: str, root: str = REPO) -> List[str]:
    problems: List[str] = []
    full = os.path.join(root, path)
    if not os.path.exists(full):
        return [f"{path}: listed in docs_check.DOCS but missing"]
    with open(full) as f:
        text = f.read()
    doc_dir = os.path.dirname(full)

    if text.count("```") % 2:
        problems.append(f"{path}: unbalanced ``` code fences")

    fence_langs_and_bodies = re.findall(r"```(\w*)\n(.*?)```", text, re.S)
    prose = re.sub(r"```.*?```", "", text, flags=re.S)

    for target in _LINK.findall(prose):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#", 1)[0]
        if target and not _exists(target, doc_dir, root):
            problems.append(f"{path}: dead link -> {target}")

    for ref in _CODE_PATH.findall(prose):
        if ref.startswith("out/"):
            continue  # generated outputs need not exist in a clean checkout
        if "/" in ref and not _exists(ref, doc_dir, root):
            problems.append(f"{path}: stale file reference `{ref}`")

    for lang, body in fence_langs_and_bodies:
        if lang not in ("sh", "bash", "console", ""):
            continue
        for mod in _PY_MODULE.findall(body):
            if _local_package(mod, root) and not _module_file(mod, root):
                problems.append(
                    f"{path}: fenced command references missing module "
                    f"'python -m {mod}'"
                )
        for script in _PY_FILE.findall(body):
            if not _exists(script, doc_dir, root):
                problems.append(
                    f"{path}: fenced command references missing file "
                    f"'python {script}'"
                )
    return problems


def collect(docs=DOCS, root: str = REPO) -> List[str]:
    """All problems over ``docs``, silently (the spars-lint driver path)."""
    problems: List[str] = []
    for doc in docs:
        problems.extend(check_doc(doc, root=root))
    return problems


def main(docs=DOCS, root: str = REPO) -> List[str]:
    problems = collect(docs, root=root)
    for p in problems:
        print(f"docs-check: {p}", file=sys.stderr)
    if not problems:
        print(f"docs-check: {len(docs)} documents OK")
    return problems


if __name__ == "__main__":
    sys.exit(1 if main() else 0)
