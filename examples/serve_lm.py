"""Batched serving example: continuous-batching decode over any assigned
architecture (reduced config on CPU; the same ``serve_step`` lowers for the
decode_32k / long_500k dry-run cells on the production mesh).

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    result = serve_driver.main(
        [
            "--arch", args.arch, "--reduced",
            "--requests", str(args.requests),
            "--slots", str(args.slots),
            "--prompt-len", "16",
            "--max-new", str(args.max_new),
            "--cache-len", "64",
        ]
    )
    print(
        f"served {result['requests']} requests, "
        f"{result['tokens_per_s']} tok/s, mean TTFT {result['mean_ttft_s']}s"
    )


if __name__ == "__main__":
    main()
