"""End-to-end driver example: train a ~100M-parameter LM for a few hundred
steps with the full production path — sharded init, deterministic data
pipeline, async checkpointing with restart-from-latest, straggler watchdog,
cosine LR schedule, gradient accumulation.

    PYTHONPATH=src python examples/train_lm.py              # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --tiny       # smoke (~1 min)

Re-running the same command resumes from the last checkpoint (kill it
mid-run to see the fault-tolerance path).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ArchConfig, ARCH_REGISTRY, REDUCED_REGISTRY
from repro.launch import train as train_driver


def register_lm100m():
    """A ~100M-param dense config (internlm2 family, scaled)."""

    def full() -> ArchConfig:
        return ArchConfig(
            name="lm-100m",
            family="dense",
            n_layers=10,
            d_model=640,
            n_heads=10,
            n_kv_heads=5,
            d_ff=2560,
            vocab_size=32000,
            dtype_name="float32",  # CPU example; bf16 on TPU
            remat=False,
        )

    ARCH_REGISTRY["lm-100m"] = full
    REDUCED_REGISTRY["lm-100m"] = full
    from repro.configs import base as cfg_base

    cfg_base._ARCH_MODULES["lm-100m"] = "examples.train_lm"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true", help="1-minute smoke run")
    args = ap.parse_args()

    register_lm100m()
    argv = [
        "--arch", "lm-100m",
        "--steps", str(20 if args.tiny else args.steps),
        "--batch", str(2 if args.tiny else args.batch),
        "--seq", str(64 if args.tiny else args.seq),
        "--accum", "2",
        "--ckpt-every", "25",
        "--ckpt-dir", os.path.join(os.path.dirname(__file__), "..", "out", "ckpt_lm"),
        "--lr", "6e-4",
    ]
    result = train_driver.main(argv)
    drop = (result["first_loss"] or 0) - (result["last_loss"] or 0)
    print(f"loss drop over run: {drop:.3f}")
    assert drop > 0, "model did not learn"


if __name__ == "__main__":
    main()
