"""Quickstart (paper Fig. 3): 200 random jobs on 16 nodes under EASY
Backfilling + PSUS with a 50-second timeout shutdown policy and the
terminate-overrun policy; writes the Gantt chart (CSV always, PNG when
matplotlib is available) and prints the aggregate metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import engine
from repro.core.gantt import intervals_from_log, render_png, write_csv
from repro.core.metrics import metrics_from_state, np_state
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import preset
from repro.workloads.platform import PlatformSpec


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "out")
    os.makedirs(out_dir, exist_ok=True)

    # paper Fig. 3 setup
    workload = preset("fig3_small")  # 200 jobs, 16 nodes
    platform = PlatformSpec(nb_nodes=16, t_switch_on=60, t_switch_off=90)
    config = EngineConfig(
        base=BasePolicy.EASY,
        psm=PSMVariant.PSUS,
        timeout=50,               # 50-second timeout shutdown policy
        terminate_overrun=True,   # terminate jobs exceeding requested wall-time
        record_gantt=True,
    )

    s0 = engine.init_state(platform, workload, config)
    # specialize=True: a single-config run folds the policy flags in as
    # closure constants, so only this scheduler's rules are compiled
    const = engine.make_const(platform, config, specialize=True)
    s, log = engine.run_sim_gantt(
        s0, const, config, max_batches=engine.default_batch_cap(len(workload))
    )

    m = metrics_from_state(s, platform.power_active)
    print("EASY PSUS, timeout=50s, terminate-overrun — 200 jobs / 16 nodes")
    for k, v in m.row().items():
        print(f"  {k:20s} {v}")

    intervals = intervals_from_log(log)
    csv_path = os.path.join(out_dir, "gantt_quickstart.csv")
    write_csv(intervals, csv_path)
    print(f"gantt CSV  -> {csv_path} ({len(intervals)} intervals)")

    d = np_state(s)
    terminated = [int(j) for j in d["job_terminated"].nonzero()[0]]
    png_path = os.path.join(out_dir, "gantt_quickstart.png")
    if render_png(intervals, png_path, terminated_jobs=terminated,
                  title="EASY PSUS, 50 s timeout, terminate-overrun"):
        print(f"gantt PNG  -> {png_path}")
    else:
        print("matplotlib not installed; skipped PNG")


if __name__ == "__main__":
    main()
