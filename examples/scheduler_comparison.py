"""Paper Figs. 4/5 in miniature: the six schedulers (FCFS/EASY x PSUS /
PSAS(Auto On) / PSAS+IPM) swept over shutdown timeouts on a NASA-like
workload — the WHOLE 6 x 6 grid is ONE vmapped XLA program (the traced
policy axis, via the declarative `repro.experiments` layer) — printing the
energy-vs-wait trade-off table and writing a plot when matplotlib exists.

    PYTHONPATH=src python examples/scheduler_comparison.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import experiments
from repro.core.policy import scheduler_labels
from repro.workloads.generator import PRESETS

# the six timeout-based schedulers (policy.from_label registry)
SCHEDULERS = tuple(l for l in scheduler_labels() if "AlwaysOn" not in l)
TIMEOUTS_MIN = [5, 10, 20, 30, 45, 60]


def main():
    exp = experiments.Experiment(
        name="scheduler_comparison",
        workload={"preset": "nasa_ipsc", "n_jobs": 500},
        platform=PRESETS["nasa_ipsc"].nb_res,  # paper Table 3 power model
        schedulers=SCHEDULERS,
        timeouts=tuple(t * 60 for t in TIMEOUTS_MIN),
    )
    result = experiments.run(exp)
    assert result.n_compiles in (None, 1), result.n_compiles

    by_sched = {name: [] for name in SCHEDULERS}
    for row in result.rows:
        by_sched[row["scheduler"]].append(row)

    print(f"{'scheduler':20s} " + " ".join(f"t={t:>3d}m" for t in TIMEOUTS_MIN))
    for name, rows in by_sched.items():
        print(
            f"{name:20s} "
            + " ".join(f"{r['total_energy_kwh']:6.0f}" for r in rows)
            + "   kWh"
        )
        print(
            f"{'':20s} "
            + " ".join(f"{r['mean_wait_s']:6.0f}" for r in rows)
            + "   mean wait (s)"
        )
    print(
        f"# 6 schedulers x {len(TIMEOUTS_MIN)} timeouts = "
        f"{result.n_compiles if result.n_compiles is not None else '?'} "
        f"compiled program(s), {result.wall_s:.1f}s"
    )

    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt

        fig, (ax1, ax2) = plt.subplots(1, 2, figsize=(12, 4.5))
        for name, rows in by_sched.items():
            ax1.plot(TIMEOUTS_MIN, [r["total_energy_kwh"] for r in rows],
                     marker="o", label=name)
            ax2.plot(TIMEOUTS_MIN, [r["mean_wait_s"] for r in rows], marker="o")
        ax1.set_xlabel("shutdown timeout (min)")
        ax1.set_ylabel("total energy (kWh)")
        ax2.set_xlabel("shutdown timeout (min)")
        ax2.set_ylabel("mean wait (s)")
        ax1.legend(fontsize=7)
        fig.tight_layout()
        out = os.path.join(os.path.dirname(__file__), "..", "out",
                           "scheduler_comparison.png")
        os.makedirs(os.path.dirname(out), exist_ok=True)
        fig.savefig(out, dpi=130)
        print(f"plot -> {out}")
    except ImportError:
        print("matplotlib not installed; skipped plot")


if __name__ == "__main__":
    main()
