"""Paper Figs. 4/5 in miniature: the six schedulers (FCFS/EASY x PSUS /
PSAS(Auto On) / PSAS+IPM) swept over shutdown timeouts on a NASA-like
workload — one vmapped XLA program per scheduler — printing the
energy-vs-wait trade-off table and writing a plot when matplotlib exists.

    PYTHONPATH=src python examples/scheduler_comparison.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import engine
from repro.core.policy import from_label, scheduler_labels
from repro.core.types import EngineConfig
from repro.workloads.generator import PRESETS, GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec

# the six timeout-based schedulers (policy.from_label registry)
SCHEDULERS = tuple(l for l in scheduler_labels() if "AlwaysOn" not in l)
TIMEOUTS_MIN = [5, 10, 20, 30, 45, 60]


def main():
    gcfg = GeneratorConfig(**{**PRESETS["nasa_ipsc"].__dict__, "n_jobs": 500})
    wl = generate_workload(gcfg)
    plat = PlatformSpec(nb_nodes=gcfg.nb_res)  # paper Table 3 power model

    results = {}
    print(f"{'scheduler':20s} " + " ".join(f"t={t:>3d}m" for t in TIMEOUTS_MIN))
    for name in SCHEDULERS:
        base, pol = from_label(name)
        cfg = EngineConfig(base=base, policy=pol, timeout=300)
        # one compiled program per scheduler: engine.sweep vmaps the timeouts
        batch = engine.sweep(plat, wl, [t * 60 for t in TIMEOUTS_MIN], cfg)
        ms = list(batch.metrics)
        results[name] = ms
        print(
            f"{name:20s} "
            + " ".join(f"{m.total_energy_j/3.6e6:6.0f}" for m in ms)
            + "   kWh"
        )
        print(
            f"{'':20s} "
            + " ".join(f"{m.mean_wait_s:6.0f}" for m in ms)
            + "   mean wait (s)"
        )

    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt

        fig, (ax1, ax2) = plt.subplots(1, 2, figsize=(12, 4.5))
        for name, ms in results.items():
            ax1.plot(TIMEOUTS_MIN, [m.total_energy_j / 3.6e6 for m in ms],
                     marker="o", label=name)
            ax2.plot(TIMEOUTS_MIN, [m.mean_wait_s for m in ms], marker="o")
        ax1.set_xlabel("shutdown timeout (min)")
        ax1.set_ylabel("total energy (kWh)")
        ax2.set_xlabel("shutdown timeout (min)")
        ax2.set_ylabel("mean wait (s)")
        ax1.legend(fontsize=7)
        fig.tight_layout()
        out = os.path.join(os.path.dirname(__file__), "..", "out",
                           "scheduler_comparison.png")
        os.makedirs(os.path.dirname(out), exist_ok=True)
        fig.savefig(out, dpi=130)
        print(f"plot -> {out}")
    except ImportError:
        print("matplotlib not installed; skipped plot")


if __name__ == "__main__":
    main()
