"""Train an RL power manager (paper refs [7],[24] analogue): A2C agent
controls node power transitions while EASY Backfilling dispatches jobs;
reward balances wasted energy against job waiting (paper's energy/wait
trade-off). Evaluates the trained agent against the timeout-policy
baselines on held-out workloads.

    PYTHONPATH=src python examples/train_rl_power_manager.py [--updates 150]
"""
import argparse
import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.metrics import metrics_from_state
from repro.core.rl.a2c import A2CConfig, train_a2c
from repro.core.rl.env import EnvConfig, HPCGymEnv
from repro.core.rl.networks import policy_apply
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec


def evaluate_policy(params, plat, wl, ecfg):
    """Greedy rollout of the trained agent on one workload."""
    env = HPCGymEnv(plat, wl, ecfg)
    obs = env.reset()
    done = False
    steps = 0
    while not done and steps < ecfg.max_steps:
        logits, _ = policy_apply(params, jnp.asarray(obs))
        action = int(jnp.argmax(logits))
        obs, _, done, _ = env.step(action)
        steps += 1
    return metrics_from_state(env.state.sim, plat.power_active)


def evaluate_baseline(plat, wl, timeout):
    cfg = EngineConfig(base=BasePolicy.EASY, psm=PSMVariant.PSUS, timeout=timeout)
    s = engine.simulate(plat, wl, cfg)
    return metrics_from_state(s, plat.power_active)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=120)
    ap.add_argument("--envs", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument(
        "--curriculum", action="store_true",
        help="staged workload-difficulty ramp (paper ref [7] analogue)",
    )
    ap.add_argument(
        "--save", default=None, metavar="DIR",
        help="save the trained policy (versioned header) so "
             "`repro.launch.sim --scheduler 'EASY RL'` can load it",
    )
    args = ap.parse_args()

    plat = PlatformSpec(nb_nodes=args.nodes, t_switch_on=600, t_switch_off=900)
    train_wls = [
        generate_workload(
            GeneratorConfig(
                n_jobs=48, nb_res=args.nodes, mean_interarrival=1500.0, seed=s
            )
        )
        for s in range(args.envs)
    ]
    eval_wls = [
        generate_workload(
            GeneratorConfig(
                n_jobs=48, nb_res=args.nodes, mean_interarrival=1500.0, seed=1000 + s
            )
        )
        for s in range(3)
    ]
    ecfg = EnvConfig(
        engine=EngineConfig(
            psm=PSMVariant.RL, base=BasePolicy.EASY, rl_decision_interval=600
        ),
        max_steps=512,
        reward="waste_wait",
    )
    acfg = A2CConfig(
        n_envs=args.envs, n_steps=16, n_updates=args.updates, lr=3e-4, seed=0
    )

    print(f"training A2C power manager: {args.envs} envs x {args.updates} updates"
          + (" (curriculum)" if args.curriculum else ""))
    hist_rewards = []

    def progress(i, m):
        hist_rewards.append(m["mean_reward"])
        if (i + 1) % 20 == 0:
            avg = float(np.mean(hist_rewards[-20:]))
            print(
                f"  update {i+1:4d}  reward(ma20)={avg:+.4f} "
                f"entropy={m['entropy']:.3f}"
            )

    if args.curriculum:
        from repro.core.rl.curriculum import default_curriculum, train_a2c_curriculum

        target = GeneratorConfig(
            n_jobs=48, nb_res=args.nodes, mean_interarrival=1500.0, seed=0
        )
        stages = default_curriculum(
            target, n_stages=3, updates_per_stage=max(args.updates // 3, 1)
        )
        params, history = train_a2c_curriculum(
            plat, ecfg, stages, acfg,
            progress=lambda s, i, m: progress(i + s * (args.updates // 3), m),
        )
    else:
        params, history = train_a2c(plat, train_wls, ecfg, acfg, progress=progress)

    early = float(np.mean([h["mean_reward"] for h in history[:10]]))
    late = float(np.mean([h["mean_reward"] for h in history[-10:]]))
    print(f"mean reward: first 10 updates {early:+.4f} -> last 10 {late:+.4f}")

    if args.save:
        from repro.training.checkpoint import save_policy

        save_policy(
            args.save, params,
            obs_size=ecfg.obs_size, n_actions=ecfg.n_actions,
            feature=ecfg.feature, action=ecfg.action,
            n_levels=ecfg.n_action_levels, hidden=acfg.hidden,
            feature_window=ecfg.feature_window,
            grouped=ecfg.engine.policy.grouped, n_groups=ecfg.n_groups,
        )
        print(f"policy checkpoint -> {args.save}")

    print("\nevaluation on held-out workloads (energy kWh / mean wait s):")
    print(f"{'policy':28s} {'energy':>10s} {'wait':>8s}")
    for i, wl in enumerate(eval_wls):
        m_rl = evaluate_policy(params, plat, wl, ecfg)
        rows = [("A2C power manager", m_rl)]
        for t_min in (5, 30):
            rows.append(
                (f"EASY PSUS timeout={t_min}m",
                 evaluate_baseline(plat, wl, t_min * 60))
            )
        rows.append(("EASY always-on",
                     evaluate_baseline(plat, wl, None)))
        for name, m in rows:
            print(
                f"  wl{i} {name:24s} {m.total_energy_j/3.6e6:10.1f} "
                f"{m.mean_wait_s:8.0f}"
            )


if __name__ == "__main__":
    main()
