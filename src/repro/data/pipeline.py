"""Deterministic, restart-safe LM data pipeline.

The container is offline, so the corpus is synthetic — but the pipeline has
the production properties that matter for the framework:

* **Deterministic addressing**: batch ``i`` is a pure function of
  (seed, i), so a restarted run consumes the exact same stream — the
  checkpoint's ``step`` is the only data-pipeline state (no iterator
  pickling, no skew between hosts).
* **Host-sharded**: each data-parallel host materializes only its slice
  (``host_id/num_hosts``) of the global batch, then device_puts against the
  batch sharding — no host ever holds the global batch.
* **Async prefetch**: a double-buffered background thread overlaps host
  batch synthesis with device compute.

The synthetic distribution is a Zipfian unigram mix with Markov bigram
structure (so losses are non-degenerate and compressible — useful for the
train-for-a-few-hundred-steps example to show a real learning curve).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    zipf_a: float = 1.2
    markov_period: int = 16

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def batch_at(self, index: int) -> Dict[str, np.ndarray]:
        """The host's shard of global batch ``index`` (pure function)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index, self.host_id])
        )
        b, s, v = self.host_batch, self.seq_len, self.vocab_size
        # Zipf unigram over the vocab, clipped into range
        base = rng.zipf(self.zipf_a, size=(b, s)).astype(np.int64)
        tokens = (base - 1) % v
        # Markov structure: every markov_period-th token repeats its
        # predecessor's bucket, giving bigram signal a model can learn
        rep = (np.arange(s) % self.markov_period) == (self.markov_period - 1)
        tokens[:, 1:][:, rep[1:]] = tokens[:, :-1][:, rep[1:]]
        return {"tokens": tokens.astype(np.int32)}


def make_batch_iterator(
    stream: TokenStream,
    start_index: int = 0,
    prefetch: int = 2,
    extra_fn=None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Background-threaded prefetching iterator starting at ``start_index``.

    ``extra_fn(batch, index)`` can append modality-stub tensors
    (image_embeds / encoder_frames) for the VLM/audio archs.
    """
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        i = start_index
        while not stop.is_set():
            b = stream.batch_at(i)
            if extra_fn is not None:
                b = extra_fn(b, i)
            q.put(b)
            i += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()
            try:
                q.get_nowait()  # unblock the producer
            except queue.Empty:
                pass

    return _Iter()
