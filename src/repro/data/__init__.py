"""Data pipeline: deterministic synthetic LM token streams + SWF job traces."""
from repro.data.pipeline import TokenStream, make_batch_iterator

__all__ = ["TokenStream", "make_batch_iterator"]
