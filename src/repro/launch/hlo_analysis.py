"""Post-SPMD HLO cost analysis with loop trip-count accounting.

XLA's built-in ``compiled.cost_analysis()`` visits every computation ONCE, so
programs built around ``lax.scan`` (our scan-over-layers stacks) under-count
FLOPs/bytes/collectives by the loop trip count. This module parses the
optimized post-SPMD HLO text (``compiled.as_text()``) instead:

1. split the module into computations (headers are column-0 lines ending in
   ``{``; bodies are the indented lines until the closing ``}``);
2. build the call graph — ``while`` ops contribute (body x trip,
   cond x trip+1) edges, fusions/reduces/conditionals contribute x1 edges —
   and propagate execution counts from ENTRY through the DAG in topological
   order. Trip counts come from the ``known_trip_count`` backend_config that
   XLA attaches to scheduled while ops (fallback: the constant compared
   against the induction variable in the condition computation);
3. account per executed instruction:
   * FLOPs: ``dot`` ops (2 x result x contraction size) and ``convolution``
     ops — the standard matmul-FLOPs convention used for MFU;
   * HBM bytes: result + operand bytes of materializing ops (fusion, dot,
     copy, reduce, sort, dynamic slices, collectives, custom-calls) at the
     *call-site* level — lines inside fusion bodies are excluded so interior
     values (which live in registers/VMEM) are not miscounted as HBM traffic;
   * collective wire bytes per device via ring-algorithm formulas.

Shapes in post-SPMD HLO are already per-partition, so every number reported
here is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_TRIP_RE = re.compile(r'known_trip_count.{0,4}?n.{0,4}?(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"%?([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_BYTES_OPS = (
    "fusion", "dot", "copy", "reduce", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "transpose", "convolution",
    "sort", "concatenate", "convert", "broadcast", "iota",
    "select-and-scatter", "custom-call", "reduce-window", "pad", "slice",
    "reverse", "cholesky", "triangular-solve", "rng", "rng-bit-generator",
) + _COLLECTIVES


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    is_entry: bool = False


def _split_computations(hlo: str) -> Dict[str, Computation]:
    """Split HLO text into computations by column-0 headers ending in '{'."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        if cur is None:
            if not raw or raw[0].isspace():
                continue
            if not raw.rstrip().endswith("{"):
                continue
            m = _HEADER_RE.match(raw)
            if m:
                cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
        else:
            s = raw.strip()
            if s == "}":
                comps[cur.name] = cur
                cur = None
            elif s:
                cur.lines.append(s)
    return comps


def _trip_count_fallback(cond: Computation) -> int:
    """Trip count from the constant compared against the induction var."""
    consts = {
        m.group(1): int(m.group(2))
        for ln in cond.lines
        for m in [_CONST_RE.search(ln)]
        if m
    }
    for ln in cond.lines:
        if "compare(" in ln and "ROOT" in ln:
            inner = ln.split("compare(", 1)[1]
            for name, val in consts.items():
                if f"%{name}" in inner or f"({name}" in inner or f" {name}" in inner:
                    return val
    return max(consts.values(), default=1)


def _edges(
    comps: Dict[str, Computation],
) -> Tuple[Dict[str, List[Tuple[str, float]]], Set[str]]:
    """(caller -> [(callee, per-execution multiplicity)]); fusion interiors."""
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    fusion_interior: Set[str] = set()
    for name, comp in comps.items():
        for ln in comp.lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(ln)
                if tm:
                    trip = int(tm.group(1))
                elif cond_name in comps:
                    trip = _trip_count_fallback(comps[cond_name])
                else:
                    trip = 1
                if body_name in comps:
                    edges[name].append((body_name, float(trip)))
                if cond_name in comps:
                    edges[name].append((cond_name, float(trip + 1)))
                continue
            bm = _BRANCHES_RE.search(ln)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b in comps:
                        edges[name].append((b, 1.0))
                continue
            cm = _CALLS_RE.search(ln)
            if cm and cm.group(1) in comps:
                edges[name].append((cm.group(1), 1.0))
                if "fusion(" in ln:
                    fusion_interior.add(cm.group(1))
    return edges, fusion_interior


def _multipliers(comps: Dict[str, Computation]) -> Tuple[Dict[str, float], Set[str]]:
    """Execution count per computation via topological DAG propagation."""
    edges, fusion_interior = _edges(comps)
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {name: 1.0 for name in comps}, fusion_interior
    # Kahn topological order over the call DAG reachable from entry
    indeg: Dict[str, int] = defaultdict(int)
    seen = {entry}
    stack = [entry]
    while stack:
        u = stack.pop()
        for v, _ in edges.get(u, ()):
            indeg[v] += 1
            if v not in seen:
                seen.add(v)
                stack.append(v)
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry] + [n for n in seen if n != entry and indeg[n] == 0]
    queue = list(order)
    while queue:
        u = queue.pop(0)
        for v, k in edges.get(u, ()):
            mult[v] += mult[u] * k
            indeg[v] -= 1
            if indeg[v] == 0:
                queue.append(v)
    return {name: mult.get(name, 0.0) for name in comps}, fusion_interior


_LHS_NAME_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=")
_OPERAND_NAME_RE = re.compile(r"%?([\w\.\-]+)")


def _symbol_table(comp: Computation) -> Dict[str, List[int]]:
    """name -> result dims for every instruction in the computation."""
    table: Dict[str, List[int]] = {}
    for ln in comp.lines:
        m = _LHS_NAME_RE.match(ln)
        if not m or "=" not in ln:
            continue
        rhs = ln.split("=", 1)[1]
        shapes = _shapes_in(rhs.split("(", 1)[0])
        if shapes:
            table[m.group(1)] = shapes[0][1]
    return table


def _dot_flops(line: str, symbols: Dict[str, List[int]]) -> float:
    """2 x prod(result) x prod(contracting dims of lhs)."""
    rhs = line.split("=", 1)[1]
    shapes = _shapes_in(rhs.split("dot(", 1)[0])  # result shape(s)
    if not shapes:
        return 0.0
    result_elems = 1
    for d in shapes[0][1]:
        result_elems *= d
    inner = rhs.split("dot(", 1)[1].split(")", 1)[0]
    # scheduled HLO prints operands as bare names; resolve via symbol table
    op_shapes = _shapes_in(inner)
    lhs_dims: List[int] = op_shapes[0][1] if op_shapes else []
    if not lhs_dims:
        names = [t.strip() for t in inner.split(",")]
        if names:
            nm = _OPERAND_NAME_RE.match(names[0].lstrip("%"))
            if nm and nm.group(1) in symbols:
                lhs_dims = symbols[nm.group(1)]
    if not lhs_dims:
        return 0.0
    m = _DOT_DIMS_RE.search(line)
    contract = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * result_elems * contract


_CONV_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")


def _conv_flops(line: str) -> float:
    """2 x result_elems x (in_channels x prod(window)) — standard conv MACs."""
    rhs = line.split("=", 1)[1]
    res = _shapes_in(rhs.split("convolution(", 1)[0])
    if not res:
        return 0.0
    result_elems = 1
    for d in res[0][1]:
        result_elems *= d
    inner = rhs.split("convolution(", 1)[1]
    ops = _shapes_in(inner.split(")", 1)[0])
    window = 1
    wm = _CONV_WINDOW_RE.search(line)
    if wm:
        for d in wm.group(1).split("x"):
            window *= int(d)
    # rhs operand is the kernel [*window, in_c, out_c]-ish; use kernel size
    in_c = 1
    if len(ops) >= 2 and ops[1][1]:
        kernel_elems = 1
        for d in ops[1][1]:
            kernel_elems *= d
        out_c = res[0][1][-1] if res[0][1] else 1
        in_c_window = kernel_elems // max(out_c, 1)
        return 2.0 * result_elems * in_c_window
    return 2.0 * result_elems * window * in_c


def _op_kind(rhs: str) -> Optional[str]:
    # rhs looks like: `bf16[8,16]{1,0} fusion(...), kind=kLoop, calls=...`
    m = re.search(r"[\}\s\]]([a-z][\w\-]*)\(", " " + rhs)
    if m:
        return m.group(1).replace("-start", "")
    return None


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze_hlo(hlo: str, n_devices: int) -> HloCost:
    comps = _split_computations(hlo)
    mult, fusion_interior = _multipliers(comps)
    cost = HloCost(
        collective_bytes=defaultdict(float),
        collective_counts=defaultdict(float),
    )
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        interior = name in fusion_interior
        symbols = _symbol_table(comp)
        for ln in comp.lines:
            if "=" not in ln:
                continue
            rhs = ln.split("=", 1)[1].strip()
            kind = _op_kind(rhs)
            if kind is None:
                continue
            if kind == "dot":
                cost.flops += m * _dot_flops(ln, symbols)
            elif kind == "convolution":
                cost.flops += m * _conv_flops(ln)
            if interior:
                continue  # fused interiors: no HBM traffic, no wire traffic
            if kind in _COLLECTIVES and "-done" not in rhs:
                op_pos = rhs.find(kind)
                r = float(_shape_bytes(rhs[:op_pos]))
                g = _group_size(ln, n_devices)
                if g > 1 and r > 0:
                    if kind == "all-gather":
                        # result is the gathered (full) shape
                        wire = r * (g - 1) / g
                    elif kind == "reduce-scatter":
                        # result is the scattered (1/g) shape
                        wire = r * (g - 1)
                    elif kind == "all-reduce":
                        wire = 2 * r * (g - 1) / g
                    elif kind == "all-to-all":
                        wire = r * (g - 1) / g
                    else:  # collective-permute: one hop
                        wire = r
                    cost.collective_bytes[kind] += m * wire
                    cost.collective_counts[kind] += m
            if kind in _BYTES_OPS:
                # result + operands (bytes-accessed convention)
                op_pos = rhs.find(kind + "(")
                if op_pos < 0:
                    op_pos = rhs.find(kind + "-start(")
                result_b = _shape_bytes(rhs[:op_pos]) if op_pos > 0 else 0
                inner = rhs[op_pos:].split("(", 1)[-1]
                operand_b = _shape_bytes(inner.split("), ")[0].split("))")[0])
                cost.hbm_bytes += m * (result_b + operand_b)
    cost.collective_bytes = dict(cost.collective_bytes)
    cost.collective_counts = dict(cost.collective_counts)
    return cost


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_wire_bytes: float,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float,
) -> Dict[str, float]:
    t_compute = flops_per_device / peak_flops
    t_memory = hbm_bytes_per_device / hbm_bw
    t_collective = collective_wire_bytes / ici_bw
    terms = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
    }
    dominant = max(terms, key=terms.get)
    out = dict(terms)
    out["dominant"] = dominant.replace("t_", "").replace("_s", "")
    bound = max(t_compute, t_memory, t_collective)
    out["roofline_step_s"] = bound
    out["compute_fraction"] = t_compute / bound if bound > 0 else 0.0
    return out
