"""End-to-end LM training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir out/ckpt

Features exercised (all CPU-testable; the same code path drives the
production mesh on TPU):

* restart-from-latest: re-running the command resumes from the newest
  checkpoint (atomic-publish Checkpointer; see training/checkpoint.py)
* async checkpointing every ``--ckpt-every`` steps, off the step path
* deterministic data: the stream index is derived from the restored step,
  so a crash/restart consumes the exact token sequence an uninterrupted
  run would have (training/pipeline determinism test covers this)
* straggler watchdog on step wall-time (training/stragglers.py)
* optional gradient compression (--compression int8|topk)
* gradient accumulation (--accum) = compute/comm overlap mechanism
* simulated failure injection (--fail-at N) for the fault-tolerance test:
  the process exits hard after step N, *after* the async checkpoint at the
  last --ckpt-every boundary
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.data.pipeline import TokenStream, make_batch_iterator
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.sharding import batch_shardings, params_shardings
from repro.training.checkpoint import Checkpointer
from repro.training.optimizer import cosine_schedule
from repro.training.stragglers import StepWatchdog, WatchdogConfig
from repro.training.train_step import TrainStepConfig, make_optimizer, make_train_step


def modality_extras(cfg):
    """Stub-frontend tensors for VLM/audio archs (precomputed embeddings)."""

    def fn(batch: Dict[str, np.ndarray], index: int) -> Dict[str, np.ndarray]:
        b = batch["tokens"].shape[0]
        rng = np.random.default_rng(index)
        if cfg.n_image_embeds:
            batch["image_embeds"] = rng.normal(
                size=(b, cfg.n_image_embeds, cfg.d_model)
            ).astype(np.float32)
        if cfg.encoder_layers:
            batch["encoder_frames"] = rng.normal(
                size=(b, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32)
        return batch

    return fn


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compression", default=None, choices=[None, "int8", "topk"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="out/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, reduced=args.reduced)
    mesh = make_host_mesh(args.model_parallel)
    model = build_model(cfg)

    sched = cosine_schedule(args.lr, warmup_steps=20, total_steps=args.steps)
    opt = make_optimizer(cfg.optimizer, sched)
    step_fn = make_train_step(
        model,
        opt,
        TrainStepConfig(accum_steps=args.accum, compression=args.compression),
    )

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(args.seed))
    p_shard = params_shardings(cfg, mesh, params_shapes)

    ckpt = Checkpointer(os.path.join(args.ckpt_dir, cfg.name.replace("/", "_")))
    start_step = ckpt.latest_step()
    with mesh:
        if start_step is None:
            start_step = 0
            params = jax.jit(model.init, out_shardings=p_shard)(
                jax.random.PRNGKey(args.seed)
            )
            opt_state = opt.init(params)
            print(f"[train] fresh init: {model.n_params(params):,} params")
        else:
            _, state = ckpt.restore(
                {"params": params_shapes, "opt": jax.eval_shape(opt.init, params_shapes)},
            )
            params, opt_state = state["params"], state["opt"]
            params = jax.tree_util.tree_map(jax.device_put, params, p_shard)
            print(f"[train] resumed from step {start_step}")

        stream = TokenStream(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq,
            global_batch=args.batch,
            seed=args.seed,
        )
        it = make_batch_iterator(
            stream, start_index=start_step, extra_fn=modality_extras(cfg)
        )
        b_shapes = jax.eval_shape(lambda: stream.batch_at(0))
        step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

        watchdog = StepWatchdog(
            WatchdogConfig(patience=3, threshold=3.0),
            on_straggler=lambda s, dt, base: print(
                f"[watchdog] step {s}: {dt:.3f}s vs baseline {base:.3f}s — straggler flag"
            ),
        )

        losses = []
        t_start = time.time()
        for step in range(start_step, args.steps):
            batch = next(it)
            with watchdog:
                params, opt_state, metrics = step_jit(params, opt_state, batch)
                loss = float(metrics["loss"])
            losses.append(loss)
            if (step + 1) % args.log_every == 0:
                print(
                    f"[train] step {step+1}/{args.steps} loss={loss:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"({watchdog.history[-1]*1e3:.0f} ms)"
                )
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save_async(step + 1, {"params": params, "opt": opt_state})
            if args.fail_at is not None and step + 1 >= args.fail_at:
                ckpt.wait()
                print(f"[train] simulated failure at step {step+1}; exiting hard")
                it.close()
                os._exit(17)
        ckpt.wait()
        it.close()

    wall = time.time() - t_start
    result = {
        "arch": cfg.name,
        "steps_run": args.steps - start_step,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "wall_s": round(wall, 2),
        "straggler_flags": watchdog.fired,
    }
    print("[train] done:", json.dumps(result))
    return result


if __name__ == "__main__":
    main()
