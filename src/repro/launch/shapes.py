"""Assigned input-shape sets and ShapeDtypeStruct builders per (arch, shape).

The four LM shape sets (assignment):
  train_4k     seq 4096,    global_batch 256   -> train_step
  prefill_32k  seq 32768,   global_batch 32    -> prefill_step
  decode_32k   seq 32768,   global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288,  global_batch 1     -> serve_step; sub-quadratic
                                                  archs only (see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import Model, build_model


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPE_SETS: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether this (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "skip: pure full-attention arch at 524k context "
            "(quadratic prefill / O(ctx) KV decode; see DESIGN.md §Arch-applicability)"
        )
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for the model-input batch dict (train/prefill)."""
    b, s = shape.batch, shape.seq
    batch = {"tokens": sds((b, s), jnp.int32)}
    if cfg.n_image_embeds:
        batch["image_embeds"] = sds((b, cfg.n_image_embeds, cfg.d_model), cfg.dtype)
    if cfg.encoder_layers:
        batch["encoder_frames"] = sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return batch


def decode_specs(cfg: ArchConfig, model: Model, shape: ShapeSpec):
    """(tokens, cache, pos) ShapeDtypeStructs for serve_step."""
    b, s = shape.batch, shape.seq
    tokens = sds((b, 1), jnp.int32)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    pos = sds((), jnp.int32)
    return tokens, cache, pos


def train_accum_steps(cfg: ArchConfig, n_params: int, shape: ShapeSpec) -> int:
    """Microbatch accumulation for the train shape (keeps activations in HBM)."""
    if n_params > 1e10:
        return 8
    if n_params > 3e9:
        return 4
    return 1


def param_count(cfg: ArchConfig) -> int:
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return sum(int(jnp.prod(jnp.asarray(x.shape))) for x in jax.tree_util.tree_leaves(shapes))
