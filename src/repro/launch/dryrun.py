import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes, with real shardings and ShapeDtypeStruct inputs
(no device allocation), then extract memory / cost / collective analysis for
EXPERIMENTS.md §Dry-run and §Roofline.

MUST be run as its own process (the XLA_FLAGS line above must execute before
any jax import):

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k --mesh single --out out/dryrun

Use --arch all --shape all --mesh both for the full 40-cell sweep (plus the
paper's own spars-rl cell).
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, list_archs
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW_PER_LINK,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.launch.shapes import (
    SHAPE_SETS,
    applicable,
    batch_specs,
    decode_specs,
    train_accum_steps,
)
from repro.models import build_model
from repro.models.sharding import (
    batch_shardings,
    cache_shardings,
    params_shardings,
    replicated,
)
from repro.training.optimizer import _AdamMoments, _FactorState
from repro.training.train_step import TrainStepConfig, make_optimizer, make_train_step


def _bytes_of(shapes) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(shapes)
    )


def _sharded_bytes(shapes, shardings, mesh) -> int:
    """Per-device bytes of args under their shardings."""
    total = 0
    for x, sh in zip(
        jax.tree_util.tree_leaves(shapes), jax.tree_util.tree_leaves(shardings)
    ):
        n_shards = 1
        spec = sh.spec
        for i, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            k = int(np.prod([mesh.shape[a] for a in axes]))
            if i < len(x.shape) and x.shape[i] % k == 0:
                n_shards *= k
        total += int(np.prod(x.shape)) * x.dtype.itemsize // n_shards
    return total


def _opt_shardings(opt_shapes, p_shardings, mesh):
    from repro.training.optimizer import OptState

    rep = NamedSharding(mesh, P())
    inner = opt_shapes.inner
    if isinstance(inner, _AdamMoments):
        inner_sh = _AdamMoments(p_shardings, p_shardings)
    elif isinstance(inner, _FactorState):
        inner_sh = _FactorState(
            replicated(mesh, inner.vr), replicated(mesh, inner.vc)
        )
    elif inner is None:
        inner_sh = None
    else:
        inner_sh = replicated(mesh, inner)
    return OptState(rep, inner_sh)


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    keep_hlo: bool = False,
    accum_override: Optional[int] = None,
    config_overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return analysis record."""
    t_start = time.time()
    cfg = get_arch(arch)
    mesh_dax = ("pod", "data") if multi_pod else ("data",)
    cfg = cfg.replace(batch_axes=mesh_dax)
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    shape = SHAPE_SETS[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
    }
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    model = build_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_params = sum(
        int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params_shapes)
    )
    rec["n_params"] = n_params
    p_shard = params_shardings(cfg, mesh, params_shapes)

    with mesh:
        if shape.kind == "train":
            fsdp_sp = cfg.sharding_mode == "fsdp_sp"
            if fsdp_sp:
                # ZeRO-3 weights: optimizer/grad memory is sharded 256-way,
                # and activations are sequence-parallel — accumulation is
                # unnecessary (and would multiply the weight all-gathers)
                accum = accum_override or 1
            else:
                accum = accum_override or train_accum_steps(cfg, n_params, shape)
            rec["accum_steps"] = accum
            opt = make_optimizer(cfg.optimizer, 1e-4)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            o_shard = _opt_shardings(opt_shapes, p_shard, mesh)
            dax = tuple(a for a in ("pod", "data") if a in mesh.shape)
            g_specs = jax.tree_util.tree_map(lambda s: s.spec, p_shard)
            step = make_train_step(
                model,
                opt,
                TrainStepConfig(
                    accum_steps=accum, batch_axes=dax, grad_specs=g_specs
                ),
            )
            batch = batch_specs(cfg, shape)
            b_shard = batch_shardings(mesh, batch, shape.batch, seq_over_model=fsdp_sp)
            fn = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params_shapes, opt_shapes, batch)
            args_bytes = (
                _sharded_bytes(params_shapes, p_shard, mesh)
                + _sharded_bytes(opt_shapes, o_shard, mesh)
                + _sharded_bytes(batch, b_shard, mesh)
            )
        elif shape.kind == "prefill":
            batch = batch_specs(cfg, shape)
            b_shard = batch_shardings(mesh, batch, shape.batch)
            fn = jax.jit(model.prefill, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(params_shapes, batch)
            args_bytes = _sharded_bytes(params_shapes, p_shard, mesh) + _sharded_bytes(
                batch, b_shard, mesh
            )
        else:  # decode
            tokens, cache_shapes, pos = decode_specs(cfg, model, shape)
            c_shard = cache_shardings(cfg, mesh, cache_shapes, shape.batch, shape.seq)
            tok_shard = batch_shardings(mesh, tokens, shape.batch)
            fn = jax.jit(
                model.decode_step,
                in_shardings=(p_shard, tok_shard, c_shard, NamedSharding(mesh, P())),
                donate_argnums=(2,),
            )
            lowered = fn.lower(params_shapes, tokens, cache_shapes, pos)
            args_bytes = (
                _sharded_bytes(params_shapes, p_shard, mesh)
                + _sharded_bytes(cache_shapes, c_shard, mesh)
            )
        rec["args_bytes_per_device"] = args_bytes
        t_lower = time.time()
        rec["lower_s"] = round(t_lower - t_start, 2)

        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t_lower, 2)

    # ---- analyses ----
    # raw XLA cost_analysis (NOTE: counts scan bodies once; kept for
    # reference only — the roofline uses the trip-count-aware HLO parse)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["xla_cost_analysis_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(ma, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = repr(e)

    # trip-count-aware per-device cost from the post-SPMD HLO
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo, n_dev)
    rec["collective_wire_bytes_per_device"] = cost.collective_bytes
    rec["collective_counts"] = cost.collective_counts
    if keep_hlo:
        rec["hlo_len"] = len(hlo)

    flops_dev = cost.flops
    hbm_dev = cost.hbm_bytes
    wire = cost.total_collective_bytes
    rec["roofline"] = roofline_terms(
        flops_dev, hbm_dev, wire, PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK
    )
    rec["flops_per_device"] = flops_dev
    rec["hbm_bytes_per_device"] = hbm_dev

    # useful-FLOPs ratio: MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)
    rec["model_flops_ratio"] = None
    if shape.kind == "train":
        n_active = _active_params(cfg, n_params)
        model_flops = 6.0 * n_active * (shape.batch * shape.seq)
        rec["model_flops"] = model_flops
        rec["n_active_params"] = n_active
        total_hlo_flops = flops_dev * n_dev
        if total_hlo_flops > 0:
            rec["model_flops_ratio"] = model_flops / total_hlo_flops
    rec["status"] = "ok"
    rec["total_s"] = round(time.time() - t_start, 2)
    return rec


def _active_params(cfg, n_params: int) -> float:
    """Active params per token (MoE: shared + top_k/E of routed experts)."""
    if cfg.n_experts:
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        routed = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
            names = [getattr(k, "key", "") for k in path]
            if "moe" in names and any(
                n in ("w_gate", "w_up", "w_down") for n in names
            ):
                routed += int(np.prod(leaf.shape))
        return n_params - routed + routed * cfg.top_k / cfg.n_experts
    return float(n_params)


# ---------------------------------------------------------------------------
# the paper's own cell: distributed A2C update for the RL power manager
# ---------------------------------------------------------------------------

def lower_spars_rl(multi_pod: bool, n_envs: int = 4096) -> Dict[str, Any]:
    from repro.core.rl.a2c import A2CConfig, TrainState, make_update_fn
    from repro.core.rl.env import EnvConfig, env_reset
    from repro.core.engine import init_state, make_const
    from repro.core.rl.networks import policy_init
    from repro.core.types import EngineConfig, PSMVariant, BasePolicy
    from repro.workloads.generator import GeneratorConfig, generate_workload
    from repro.workloads.platform import PlatformSpec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": "spars-rl",
        "shape": f"a2c_envs{n_envs}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": "rl_train",
    }
    plat = PlatformSpec(nb_nodes=64)
    wl = generate_workload(GeneratorConfig(n_jobs=128, nb_res=64, seed=0))
    ecfg = EnvConfig(
        engine=EngineConfig(
            psm=PSMVariant.RL, base=BasePolicy.EASY, rl_decision_interval=600
        ),
        max_steps=256,
    )
    acfg = A2CConfig(n_envs=n_envs, n_steps=8)
    const = make_const(plat, ecfg.engine)
    sim0 = init_state(plat, wl, ecfg.engine)
    sims0_shape = jax.eval_shape(
        lambda s: jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_envs,) + a.shape), s
        ),
        sim0,
    )
    params = policy_init(jax.random.PRNGKey(0), ecfg.obs_size, ecfg.n_actions)

    def full_update(sims0, ts_params, ts_opt, key):
        from repro.training.optimizer import adamw

        opt = adamw(lr=acfg.lr)
        update, _ = make_update_fn(ecfg, const, sims0, acfg)
        env_states, obs = jax.vmap(functools.partial(env_reset, ecfg, const))(sims0)
        ts = TrainState(ts_params, ts_opt, env_states, obs, key)
        ts, metrics = update(ts)
        return ts.params, ts.opt_state, metrics

    from repro.training.optimizer import adamw

    opt = adamw(lr=acfg.lr)
    opt_shapes = jax.eval_shape(opt.init, params)
    params_shapes = jax.eval_shape(lambda: params)
    key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)

    dax = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def env_shard(x):
        spec = [None] * x.ndim
        if x.ndim >= 1 and x.shape[0] == n_envs:
            spec[0] = dax
        return NamedSharding(mesh, P(*spec))

    sims_shard = jax.tree_util.tree_map(env_shard, sims0_shape)
    rep = lambda t: jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), t
    )
    with mesh:
        fn = jax.jit(
            full_update,
            in_shardings=(sims_shard, rep(params_shapes), rep(opt_shapes), NamedSharding(mesh, P())),
        )
        lowered = fn.lower(sims0_shape, params_shapes, opt_shapes, key_shape)
        t_lower = time.time()
        rec["lower_s"] = round(t_lower - t0, 2)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t_lower, 2)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes accessed": float(ca.get("bytes accessed", 0.0)),
        }
    except Exception as e:
        rec["cost_analysis_error"] = repr(e)
    cost = analyze_hlo(compiled.as_text(), n_dev)
    rec["collective_wire_bytes_per_device"] = cost.collective_bytes
    rec["collective_counts"] = cost.collective_counts
    rec["flops_per_device"] = cost.flops
    rec["hbm_bytes_per_device"] = cost.hbm_bytes
    rec["roofline"] = roofline_terms(
        cost.flops, cost.hbm_bytes, cost.total_collective_bytes,
        PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK,
    )
    rec["status"] = "ok"
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="out/dryrun")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--spars-rl", action="store_true", help="also run the RL cell")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPE_SETS) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = lower_cell(arch, shape, mp, accum_override=args.accum)
                except Exception:
                    rec = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error",
                        "error": traceback.format_exc(),
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    rf = rec.get("roofline", {})
                    extra = (
                        f" compile={rec.get('compile_s')}s"
                        f" dominant={rf.get('dominant')}"
                        f" cf={rf.get('compute_fraction', 0):.3f}"
                    )
                elif status == "error":
                    extra = " " + rec["error"].strip().splitlines()[-1][:120]
                print(f"[{status:7s}] {tag}{extra}", flush=True)
                results.append(rec)

    if args.spars_rl:
        for mp in meshes:
            tag = f"spars-rl__{'multi' if mp else 'single'}"
            try:
                rec = lower_spars_rl(mp)
            except Exception:
                rec = {"arch": "spars-rl", "status": "error", "error": traceback.format_exc()}
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=2)
            print(f"[{rec.get('status'):7s}] {tag}", flush=True)
            results.append(rec)

    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skipped")
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_skip} skipped(documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
