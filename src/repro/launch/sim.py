"""Simulator driver — the paper's ``runner.py`` + ``simulator_config.yaml``
(§2.3.2/2.3.3), adapted to the offline container (JSON or minimal-YAML
config; PyYAML not required).

    PYTHONPATH=src python -m repro.launch.sim --config configs/sim_example.json
    PYTHONPATH=src python -m repro.launch.sim --workload wl.json --platform p.json \
        --scheduler "EASY PSUS" --timeout 900 --out out/run1
    PYTHONPATH=src python -m repro.launch.sim --experiment exp.json   # grid study

``--experiment`` runs a declarative :mod:`repro.experiments` spec: a whole
scheduler x timeout grid (x replications) as ONE compiled program.

Config keys (paper's runtime layer):
    workload:   path to workload.json | "preset:<name>" | "profiles"
    platform:   path to platform.json | node count (int); heterogeneous
                platforms use the "node_groups"/"nodes" JSON schema
                (core/SEMANTICS.md §Heterogeneity) and get per-group
                energy breakdowns in metrics.json
    scheduler:  "<FCFS|EASY> <PSUS|PSAS|PSAS+IPM|AlwaysOn|DVFS|Forecast|RL
                |RL:groups|RL:dvfs|<PSM>+DVFS|<PSM>+Forecast>"
                (the policy.from_label registry — single source of truth)
    timeout:    idle seconds before switch-off (null = never)
    forecast_horizon: rule 10 look-ahead seconds (only bites on
                '+Forecast' labels; null/0 = predict nothing)
    forecast_alpha:   rule 10 EWMA smoothing weight in [0, 1]
    terminate_overrun: bool
    node_order: "id" | "cheap" | "idle-watts" | "pack"
                (default: "cheap" when heterogeneous)
    allocation: "any" | "partition" — "partition" forbids cross-group
                allocations (core/SEMANTICS.md §Partition-aware
                allocation): a job takes the earliest-completing single
                node group that fits it, or fails to start
    rl:         {checkpoint: path, decision_interval: s}   (RL schedulers:
                checkpoint saved by training.checkpoint.save_policy; the
                greedy policy drives run_sim in-graph via an RLController)
    out:        output directory (CSV logs + metrics.json + gantt)
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import json
import os
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.core import engine
from repro.core.gantt import intervals_from_log, render_png, write_csv
from repro.core.metrics import metrics_from_state, np_state
from repro.core.policy import RLController, from_label, scheduler_labels
from repro.core.types import EngineConfig
from repro.experiments import (
    check_unknown_keys,
    resolve_platform,
    resolve_workload,
)


# single-run config keys (the experiment layer validates its own spec)
_KNOWN_KEYS = {
    "workload", "platform", "scheduler", "timeout", "terminate_overrun",
    "node_order", "allocation", "rl", "gantt", "out", "grouped_tables",
    "merge_bursts", "forecast_horizon", "forecast_alpha",
}
_KNOWN_RL_KEYS = {"checkpoint", "decision_interval"}


def _validate_keys(config: Dict[str, Any]) -> None:
    """Reject unknown config keys loudly instead of silently ignoring typos."""
    check_unknown_keys(config, _KNOWN_KEYS, "config")
    rl = config.get("rl")
    if isinstance(rl, dict):
        check_unknown_keys(rl, _KNOWN_RL_KEYS, "rl config")


def _load_mini_yaml(path: str) -> Dict[str, Any]:
    """JSON, or a flat ``key: value`` YAML subset (no PyYAML offline)."""
    with open(path) as f:
        text = f.read()
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        pass
    out: Dict[str, Any] = {}
    for line in text.splitlines():
        line = line.split("#", 1)[0].strip()
        if not line or ":" not in line:
            continue
        k, v = line.split(":", 1)
        v = v.strip()
        if v.lower() in ("null", "none", ""):
            out[k.strip()] = None
        elif v.lower() in ("true", "false"):
            out[k.strip()] = v.lower() == "true"
        else:
            try:
                out[k.strip()] = int(v)
            except ValueError:
                try:
                    out[k.strip()] = float(v)
                except ValueError:
                    out[k.strip()] = v.strip("'\"")
    return out


def _checkpoint_controller(params, meta):
    """Greedy in-graph controller: features -> argmax logits -> commands."""
    from repro.core.rl.actions import ACTION_TRANSLATORS
    from repro.core.rl.features import FEATURE_EXTRACTORS
    from repro.core.rl.networks import policy_apply

    extract = FEATURE_EXTRACTORS[meta["feature"]]
    translate = ACTION_TRANSLATORS[meta["action"]]
    window = meta.get("feature_window", 8)

    def controller(s, const):
        if meta["feature"] == "queue_window":
            obs = extract(s, const, window)
        else:
            obs = extract(s, const)
        logits, _ = policy_apply(params, obs)
        return translate(s, const, jnp.argmax(logits), meta["n_levels"])

    return controller


def _resolve_rl_policy(pol, config, plat):
    """Attach the checkpointed greedy controller to an RLController policy."""
    from repro.core.rl.features import feature_size
    from repro.training.checkpoint import load_policy

    rl = config.get("rl") or {}
    if "checkpoint" not in rl:
        raise ValueError(
            "RL schedulers need an rl: {checkpoint: <dir>} config block "
            "(a policy saved by training.checkpoint.save_policy)"
        )
    params, meta = load_policy(rl["checkpoint"])
    expected_obs = feature_size(
        meta["feature"], meta.get("feature_window", 8), plat.n_groups()
    )
    if meta["obs_size"] != expected_obs:
        raise ValueError(
            f"RL checkpoint obs_size={meta['obs_size']} does not fit this "
            f"platform ({plat.n_groups()} node groups -> obs_size "
            f"{expected_obs} for feature {meta['feature']!r}); retrain or "
            "pick a matching platform"
        )
    if bool(meta.get("grouped", False)) != pol.grouped:
        raise ValueError(
            f"RL checkpoint was trained with grouped={meta.get('grouped')} "
            f"actions but scheduler label requests grouped={pol.grouped}; "
            "use the matching 'RL' / 'RL:groups' label"
        )
    if bool(meta.get("dvfs", False)) != pol.dvfs:
        raise ValueError(
            f"RL checkpoint was trained with dvfs={meta.get('dvfs', False)} "
            f"but scheduler label requests dvfs={pol.dvfs}; use the "
            "matching 'RL' / 'RL:dvfs' label"
        )
    if pol.dvfs:
        from repro.core.rl.actions import DVFS_ACTIONS

        if meta["action"] in DVFS_ACTIONS and meta["n_levels"] != plat.n_dvfs_modes():
            raise ValueError(
                f"RL checkpoint commands {meta['n_levels']} DVFS modes but "
                f"this platform's mode-table width is {plat.n_dvfs_modes()}"
                "; mode commands would be mis-decoded — retrain or pick a "
                "matching platform"
            )
    if pol.grouped:
        from repro.core.rl.actions import action_space_size

        ckpt_groups = int(meta.get("n_groups", 1))
        expected_actions = action_space_size(
            meta["action"], meta["n_levels"], plat.n_groups()
        )
        if ckpt_groups != plat.n_groups() or meta["n_actions"] != expected_actions:
            raise ValueError(
                f"RL checkpoint was trained for {ckpt_groups} node groups "
                f"({meta['n_actions']} actions) but this platform has "
                f"{plat.n_groups()} groups ({expected_actions} actions for "
                f"action {meta['action']!r}); group-targeted commands would "
                "be mis-decoded — retrain or pick a matching platform"
            )
    controller = _checkpoint_controller(params, meta)
    return dataclasses.replace(pol, controller=controller), rl


def run(config: Dict[str, Any]) -> Dict[str, Any]:
    _validate_keys(config)
    wl = resolve_workload(config["workload"])
    plat = resolve_platform(config.get("platform", wl.nb_res))
    sched = config.get("scheduler", "EASY PSUS")
    base, pol = from_label(sched)
    rl_interval = None
    if isinstance(pol, RLController):
        pol, rl = _resolve_rl_policy(pol, config, plat)
        rl_interval = rl.get("decision_interval")
    # heterogeneous platforms default to cost-aware node selection
    # (core/SEMANTICS.md §Heterogeneity); override with node_order: id
    node_order = config.get(
        "node_order", "cheap" if plat.is_heterogeneous else "id"
    )
    ecfg = EngineConfig(
        base=base,
        policy=pol,
        timeout=config.get("timeout"),
        terminate_overrun=bool(config.get("terminate_overrun", False)),
        record_gantt=bool(config.get("gantt", True)),
        node_order=node_order,
        # §Partition-aware allocation: forbid cross-group allocations
        allocation=config.get("allocation", "any"),
        rl_decision_interval=rl_interval,
        grouped_tables=bool(config.get("grouped_tables", False)),
        merge_bursts=bool(config.get("merge_bursts", False)),
        # rule 10 operands (§Forecast) — only bite on '+Forecast' labels
        forecast_horizon=config.get("forecast_horizon"),
        forecast_alpha=float(config.get("forecast_alpha", 0.25)),
    )
    out_dir = config.get("out", "out/sim")
    os.makedirs(out_dir, exist_ok=True)

    s0 = engine.init_state(plat, wl, ecfg)
    # single-config run: fold the policy flags in as closure constants so
    # the program traces only this scheduler's rules (§Static specialization)
    const = engine.make_const(plat, ecfg, specialize=True)
    cap = engine.default_batch_cap(len(wl))
    if ecfg.record_gantt:
        s, log = engine.run_sim_gantt(s0, const, ecfg, max_batches=cap)
        intervals = intervals_from_log(log)
        write_csv(intervals, os.path.join(out_dir, "gantt.csv"))
        d = np_state(s)
        render_png(
            intervals,
            os.path.join(out_dir, "gantt.png"),
            terminated_jobs=[int(j) for j in d["job_terminated"].nonzero()[0]],
            title=f"{sched} timeout={ecfg.timeout}",
        )
    else:
        s = engine.simulate(plat, wl, ecfg)

    m = metrics_from_state(s, plat)
    if m.truncated and ecfg.record_gantt:
        # engine.simulate already warns for the non-gantt path; keep the
        # gantt path just as loud — a capped run must not read as finished
        import warnings

        warnings.warn(
            f"run {sched!r} hit the batch cap ({cap}) before completing — "
            "metrics.json describes a PARTIAL simulation ('truncated': "
            "true). Raise max_batches to run to completion.",
            RuntimeWarning,
            stacklevel=2,
        )

    # CSV job log (paper §2.3.3: "CSV outputs including job execution logs")
    d = np_state(s)
    with open(os.path.join(out_dir, "jobs.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["job", "res", "subtime", "start", "finish", "wait", "terminated"])
        arrs = wl.arrays()
        for i in range(len(wl)):
            if not d["job_exists"][i]:
                continue
            w.writerow(
                [
                    int(arrs["job_id"][i]), int(d["job_res"][i]),
                    int(d["job_subtime"][i]), int(d["job_start"][i]),
                    int(d["job_finish"][i]),
                    int(d["job_start"][i] - d["job_subtime"][i]),
                    bool(d["job_terminated"][i]),
                ]
            )
    result = {"scheduler": sched, "timeout": ecfg.timeout, **m.row()}
    with open(os.path.join(out_dir, "metrics.json"), "w") as f:
        json.dump(result, f, indent=2)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None)
    ap.add_argument(
        "--experiment", default=None, metavar="SPEC.json",
        help="run a declarative repro.experiments grid spec "
             "(scheduler x timeout grid as ONE compiled program)",
    )
    ap.add_argument("--workload", default=None)
    ap.add_argument("--platform", default=None)
    ap.add_argument(
        "--scheduler",
        default="EASY PSUS",
        metavar="LABEL",
        help="a policy.from_label scheduler label: "
             f"{', '.join(scheduler_labels(include_rl=True, include_dvfs=True))}"
             ", or '<PSM>+DVFS' / '<PSM>+Forecast' composing rule 9 / "
             "rule 10 onto any stack (e.g. 'EASY PSAS+IPM+DVFS', "
             "'EASY PSUS+Forecast')",
    )
    ap.add_argument("--timeout", type=int, default=None)
    ap.add_argument("--terminate-overrun", action="store_true")
    ap.add_argument("--out", default="out/sim")
    args = ap.parse_args(argv)
    try:
        from_label(args.scheduler)
    except KeyError as e:
        # registry validation with the did-you-mean hint, instead of a
        # frozen argparse choices list drifting from from_label
        ap.error(str(e.args[0]) if e.args else str(e))

    if args.experiment:
        # the spec is the whole study: reject single-run flags rather than
        # silently ignoring them (the same loud-failure contract as
        # _validate_keys)
        clashing = [
            f"--{name.replace('_', '-')}"
            for name in (
                "config", "workload", "platform", "scheduler", "timeout",
                "terminate_overrun", "out",
            )
            if getattr(args, name) != ap.get_default(name)
        ]
        if clashing:
            ap.error(
                f"--experiment runs a self-contained spec; {', '.join(clashing)} "
                "would be ignored — set the equivalent field in the spec file"
            )
        from repro.experiments import run_file

        result = run_file(args.experiment)
        print(result.table())
        print(
            f"# grid: {len(result.rows)} rows, "
            f"{result.n_compiles if result.n_compiles is not None else '?'} "
            f"compiled program(s), {result.wall_s:.2f}s "
            f"({result.jobs_per_s:.0f} simulated jobs/s)"
        )
        return result

    if args.config:
        config = _load_mini_yaml(args.config)
    else:
        config = {
            "workload": args.workload or "preset:fig3_small",
            "scheduler": args.scheduler,
            "timeout": args.timeout,
            "terminate_overrun": args.terminate_overrun,
            "out": args.out,
        }
        if args.platform:
            config["platform"] = (
                int(args.platform) if args.platform.isdigit() else args.platform
            )
    result = run(config)
    print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    main()
