"""Simulator driver — the paper's ``runner.py`` + ``simulator_config.yaml``
(§2.3.2/2.3.3), adapted to the offline container (JSON or minimal-YAML
config; PyYAML not required).

    PYTHONPATH=src python -m repro.launch.sim --config configs/sim_example.json
    PYTHONPATH=src python -m repro.launch.sim --workload wl.json --platform p.json \
        --scheduler "EASY PSUS" --timeout 900 --out out/run1

Config keys (paper's runtime layer):
    workload:   path to workload.json | "preset:<name>" | "profiles"
    platform:   path to platform.json | node count (int); heterogeneous
                platforms use the "node_groups"/"nodes" JSON schema
                (core/SEMANTICS.md §Heterogeneity) and get per-group
                energy breakdowns in metrics.json
    scheduler:  "FCFS|EASY PSUS|PSAS|PSAS+IPM|AlwaysOn|RL"
    timeout:    idle seconds before switch-off (null = never)
    terminate_overrun: bool
    node_order: "id" | "cheap" (default: "cheap" when heterogeneous)
    rl:         {checkpoint: path, decision_interval: s}   (scheduler "RL")
    out:        output directory (CSV logs + metrics.json + gantt)
"""
from __future__ import annotations

import argparse
import csv
import json
import os
from typing import Any, Dict, Optional

from repro.core import engine
from repro.core.gantt import intervals_from_log, render_png, write_csv
from repro.core.metrics import metrics_from_state, np_state
from repro.core.types import BasePolicy, EngineConfig, PSMVariant
from repro.workloads.generator import PRESETS, generate_workload
from repro.workloads.platform import PlatformSpec, load_platform
from repro.workloads.workload import Workload, load_workload

SCHEDULERS = {
    "FCFS PSUS": (BasePolicy.FCFS, PSMVariant.PSUS),
    "EASY PSUS": (BasePolicy.EASY, PSMVariant.PSUS),
    "FCFS PSAS": (BasePolicy.FCFS, PSMVariant.PSAS),
    "EASY PSAS": (BasePolicy.EASY, PSMVariant.PSAS),
    "FCFS PSAS+IPM": (BasePolicy.FCFS, PSMVariant.PSAS_IPM),
    "EASY PSAS+IPM": (BasePolicy.EASY, PSMVariant.PSAS_IPM),
    "EASY AlwaysOn": (BasePolicy.EASY, PSMVariant.NONE),
    "FCFS AlwaysOn": (BasePolicy.FCFS, PSMVariant.NONE),
}


def _load_mini_yaml(path: str) -> Dict[str, Any]:
    """JSON, or a flat ``key: value`` YAML subset (no PyYAML offline)."""
    with open(path) as f:
        text = f.read()
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        pass
    out: Dict[str, Any] = {}
    for line in text.splitlines():
        line = line.split("#", 1)[0].strip()
        if not line or ":" not in line:
            continue
        k, v = line.split(":", 1)
        v = v.strip()
        if v.lower() in ("null", "none", ""):
            out[k.strip()] = None
        elif v.lower() in ("true", "false"):
            out[k.strip()] = v.lower() == "true"
        else:
            try:
                out[k.strip()] = int(v)
            except ValueError:
                try:
                    out[k.strip()] = float(v)
                except ValueError:
                    out[k.strip()] = v.strip("'\"")
    return out


def resolve_workload(spec) -> Workload:
    if isinstance(spec, Workload):
        return spec
    if isinstance(spec, str) and spec.startswith("preset:"):
        name = spec.split(":", 1)[1]
        return generate_workload(PRESETS[name])
    if spec == "profiles":
        from repro.configs.job_profiles import profile_workload

        return profile_workload()
    return load_workload(spec)


def resolve_platform(spec) -> PlatformSpec:
    if isinstance(spec, PlatformSpec):
        return spec
    if isinstance(spec, int):
        return PlatformSpec(nb_nodes=spec)
    return load_platform(spec)


def run(config: Dict[str, Any]) -> Dict[str, Any]:
    wl = resolve_workload(config["workload"])
    plat = resolve_platform(config.get("platform", wl.nb_res))
    sched = config.get("scheduler", "EASY PSUS")
    base, psm = SCHEDULERS[sched]
    # heterogeneous platforms default to cost-aware node selection
    # (core/SEMANTICS.md §Heterogeneity); override with node_order: id
    node_order = config.get(
        "node_order", "cheap" if plat.is_heterogeneous else "id"
    )
    ecfg = EngineConfig(
        base=base,
        psm=psm,
        timeout=config.get("timeout"),
        terminate_overrun=bool(config.get("terminate_overrun", False)),
        record_gantt=bool(config.get("gantt", True)),
        node_order=node_order,
    )
    out_dir = config.get("out", "out/sim")
    os.makedirs(out_dir, exist_ok=True)

    s0 = engine.init_state(plat, wl, ecfg)
    const = engine.make_const(plat, ecfg)
    cap = engine.default_batch_cap(len(wl))
    if ecfg.record_gantt:
        s, log = engine.run_sim_gantt(s0, const, ecfg, max_batches=cap)
        intervals = intervals_from_log(log)
        write_csv(intervals, os.path.join(out_dir, "gantt.csv"))
        d = np_state(s)
        render_png(
            intervals,
            os.path.join(out_dir, "gantt.png"),
            terminated_jobs=[int(j) for j in d["job_terminated"].nonzero()[0]],
            title=f"{sched} timeout={ecfg.timeout}",
        )
    else:
        s = engine.simulate(plat, wl, ecfg)

    m = metrics_from_state(s, plat)

    # CSV job log (paper §2.3.3: "CSV outputs including job execution logs")
    d = np_state(s)
    with open(os.path.join(out_dir, "jobs.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["job", "res", "subtime", "start", "finish", "wait", "terminated"])
        arrs = wl.arrays()
        for i in range(len(wl)):
            if not d["job_exists"][i]:
                continue
            w.writerow(
                [
                    int(arrs["job_id"][i]), int(d["job_res"][i]),
                    int(d["job_subtime"][i]), int(d["job_start"][i]),
                    int(d["job_finish"][i]),
                    int(d["job_start"][i] - d["job_subtime"][i]),
                    bool(d["job_terminated"][i]),
                ]
            )
    result = {"scheduler": sched, "timeout": ecfg.timeout, **m.row()}
    with open(os.path.join(out_dir, "metrics.json"), "w") as f:
        json.dump(result, f, indent=2)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None)
    ap.add_argument("--workload", default=None)
    ap.add_argument("--platform", default=None)
    ap.add_argument("--scheduler", default="EASY PSUS", choices=list(SCHEDULERS))
    ap.add_argument("--timeout", type=int, default=None)
    ap.add_argument("--terminate-overrun", action="store_true")
    ap.add_argument("--out", default="out/sim")
    args = ap.parse_args(argv)

    if args.config:
        config = _load_mini_yaml(args.config)
    else:
        config = {
            "workload": args.workload or "preset:fig3_small",
            "scheduler": args.scheduler,
            "timeout": args.timeout,
            "terminate_overrun": args.terminate_overrun,
            "out": args.out,
        }
        if args.platform:
            config["platform"] = (
                int(args.platform) if args.platform.isdigit() else args.platform
            )
    result = run(config)
    print(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    main()
