"""Batched serving driver: prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --requests 16 --max-new 32

A minimal production-shaped server loop:

* slot-based **continuous batching**: a fixed decode batch of ``--slots``
  sequences; finished sequences release their slot and a queued request is
  prefilled into it (cache insert at the slot index) without stalling the
  other slots;
* prefill and decode are separate jitted programs (the decode_32k /
  long_500k dry-run cells lower exactly this ``decode_step``);
* per-request latency metrics (TTFT / TPOT) aggregated at the end.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    S = args.slots
    assert args.prompt_len + args.max_new <= args.cache_len

    def extras(b):
        out = {}
        if cfg.n_image_embeds:
            out["image_embeds"] = jnp.zeros((b, cfg.n_image_embeds, cfg.d_model), cfg.dtype)
        if cfg.encoder_layers:
            out["encoder_frames"] = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        return out

    prefill = jax.jit(lambda p, batch: model.prefill(p, batch, cache_len=args.cache_len))
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    def insert_cache(big, small, slot):
        """Write a single-sequence cache into batch slot ``slot``."""
        def leaf(b, s):
            if b is None:
                return None
            return jax.lax.dynamic_update_index_in_dim(b, s[0], slot, 1 if b.ndim > 1 else 0)
        return jax.tree_util.tree_map(
            lambda b, s: leaf(b, s), big, small,
            is_leaf=lambda a: a is None,
        )

    insert_cache_jit = jax.jit(insert_cache, donate_argnums=(0,))

    # request queue
    queue = [
        rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    t_submit = {i: time.time() for i in range(len(queue))}

    cache = model.init_cache(S, args.cache_len)
    slot_req = [-1] * S  # request id per slot
    slot_remaining = [0] * S
    cur_tokens = jnp.zeros((S, 1), jnp.int32)
    pos = args.prompt_len  # uniform prompt length => shared position counter
    ttft: Dict[int, float] = {}
    done_tokens: Dict[int, List[int]] = {}
    next_req = 0
    completed = 0
    t0 = time.time()
    decode_steps = 0

    def fill_slot(slot, cache, cur_tokens):
        nonlocal next_req
        rid = next_req
        next_req += 1
        prompt = queue[rid]
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        batch.update(extras(1))
        logits, small = prefill(params, batch)
        tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        ttft[rid] = time.time() - t_submit[rid]
        done_tokens[rid] = [int(tok)]
        slot_req[slot] = rid
        slot_remaining[slot] = args.max_new - 1
        cache = insert_cache_jit(cache, small, slot)
        cur_tokens = cur_tokens.at[slot, 0].set(tok)
        return cache, cur_tokens

    # initial fill
    for s in range(S):
        if next_req < len(queue):
            cache, cur_tokens = fill_slot(s, cache, cur_tokens)

    while completed < len(queue):
        logits, cache = decode(params, cur_tokens, cache, jnp.asarray(pos, jnp.int32))
        decode_steps += 1
        pos += 1
        nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        cur_tokens = nxt[:, None]
        for s in range(S):
            rid = slot_req[s]
            if rid < 0:
                continue
            done_tokens[rid].append(int(nxt[s]))
            slot_remaining[s] -= 1
            if slot_remaining[s] <= 0:
                completed += 1
                slot_req[s] = -1
                if next_req < len(queue):
                    cache, cur_tokens = fill_slot(s, cache, cur_tokens)
        if pos + 1 >= args.cache_len:  # out of cache: drain remaining
            for s in range(S):
                if slot_req[s] >= 0:
                    completed += 1
                    slot_req[s] = -1
            break

    wall = time.time() - t0
    total_tokens = sum(len(v) for v in done_tokens.values())
    result = {
        "arch": cfg.name,
        "requests": len(queue),
        "decode_steps": decode_steps,
        "total_tokens": total_tokens,
        "wall_s": round(wall, 2),
        "tokens_per_s": round(total_tokens / wall, 1),
        "mean_ttft_s": round(float(np.mean(list(ttft.values()))), 4),
    }
    print("[serve] done:", json.dumps(result))
    return result


if __name__ == "__main__":
    main()
