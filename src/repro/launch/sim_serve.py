"""Simulation-as-a-service: a persistent grid-study server.

    PYTHONPATH=src python -m repro.launch.sim_serve --requests req/ --once
    PYTHONPATH=src python -m repro.launch.sim_serve --requests req/   # watch
    echo spec.json | PYTHONPATH=src python -m repro.launch.sim_serve --stdin
    PYTHONPATH=src python -m repro.launch.sim_serve --smoke   # self-test

The ROADMAP's "production-scale system serving many concurrent users",
scaled to the offline container: requests are :mod:`repro.experiments`
spec JSON files dropped into a request directory (or streamed as paths /
inline JSON lines on stdin), each answered with a response JSON reporting
rows, per-request wall time, and — the point of keeping the process
*persistent* — whether the request's grid reused an already-compiled
program from ``engine._SWEEP_FNS`` (core/SEMANTICS.md §Device-sharded
sweeps: the cache key is the static trace structure plus the padded grid
width and device count, so a user re-running a study, or a second user
sweeping a same-shaped grid, pays zero compiles).

Many users' grids run *interleaved*: each request becomes a
``run(..., stream=True)`` :class:`~repro.experiments.StreamingRun` and the
service round-robins one completed chunk per active request per turn, so
a short grid is never stuck behind a long one. ``--devices`` shards every
launch's scenario axis across local devices (bit-exact either way).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

from repro.core import engine
from repro.experiments import Experiment, StreamingRun
from repro.experiments import run as run_experiment


@dataclasses.dataclass
class _Request:
    """One in-flight spec: its streaming run plus the response accounting."""

    name: str
    experiment: Experiment
    stream: StreamingRun
    t_submit: float
    rows_done: int = 0
    chunks_done: int = 0
    cache_hits: int = 0
    cache_misses: int = 0


class SimService:
    """The serving core, usable in-process (the smoke test drives it
    directly) or through the CLI loop below.

    ``submit`` turns a spec into a streaming run; ``step`` advances every
    active request by one completed chunk (round-robin — the interleave)
    and returns the responses of requests that finished this turn. Compile
    -cache reuse is attributed per request by snapshotting
    ``engine.cache_stats()`` around each chunk drain: all of a request's
    ``sweep_async`` dispatches happen inside its own ``next()`` calls, so
    the hit/miss delta belongs to the request being advanced.
    """

    def __init__(
        self,
        out_root: str,
        devices: Optional[Any] = None,
        chunk_scenarios: Optional[int] = None,
    ):
        self.out_root = out_root
        self.devices = devices
        self.chunk_scenarios = chunk_scenarios
        self.active: List[_Request] = []
        self.responses: Dict[str, dict] = {}

    def submit(self, name: str, spec: Any) -> None:
        """Queue one request. ``spec`` is an :class:`Experiment`, a parsed
        spec mapping, or spec JSON text; a spec without ``out`` lands in
        ``<out_root>/<name>/`` (metrics.json + rows.csv, written
        incrementally by the streaming runner)."""
        if isinstance(spec, Experiment):
            exp = spec
        elif isinstance(spec, str):
            exp = Experiment.from_json(spec)
        else:
            exp = Experiment(**dict(spec))
        if exp.out is None:
            exp = dataclasses.replace(
                exp, out=os.path.join(self.out_root, name)
            )
        stream = run_experiment(
            exp,
            stream=True,
            devices=self.devices,
            chunk_scenarios=self.chunk_scenarios,
        )
        self.active.append(_Request(name, exp, stream, time.perf_counter()))

    def step(self) -> List[dict]:
        """One round-robin turn: advance each active request by one chunk;
        returns (and records) the response dicts of requests that completed
        or failed this turn."""
        finished: List[dict] = []
        still: List[_Request] = []
        for req in self.active:
            before = engine.cache_stats()
            try:
                chunk_rows = next(req.stream)
            except StopIteration:
                finished.append(self._finish(req, error=None))
                continue
            except Exception as e:  # a bad spec must not kill the service
                finished.append(self._finish(req, error=f"{type(e).__name__}: {e}"))
                continue
            after = engine.cache_stats()
            req.cache_hits += after["sweep_hits"] - before["sweep_hits"]
            req.cache_misses += after["sweep_misses"] - before["sweep_misses"]
            req.rows_done += len(chunk_rows)
            req.chunks_done += 1
            still.append(req)
        self.active = still
        return finished

    def drain(self) -> List[dict]:
        """Run every queued request to completion; returns all responses."""
        out: List[dict] = []
        while self.active:
            out.extend(self.step())
        return out

    def _finish(self, req: _Request, error: Optional[str]) -> dict:
        result = req.stream.result
        response = {
            "request": req.name,
            "status": "error" if error else "done",
            "wall_s": round(time.perf_counter() - req.t_submit, 4),
            "rows": req.rows_done,
            "chunks": req.chunks_done,
            # compiled-grid reuse against the persistent engine._SWEEP_FNS
            # LRU — the serving win this process shape exists for
            "compile_cache": {
                "hits": req.cache_hits, "misses": req.cache_misses,
            },
            "devices": engine._resolve_devices(self.devices, req.experiment.engine_config()),
            "out": req.experiment.out,
        }
        if error:
            response["error"] = error
        elif result is not None:
            response["n_compiles"] = result.n_compiles
        self.responses[req.name] = response
        return response


def _write_response(responses_dir: str, response: dict) -> None:
    os.makedirs(responses_dir, exist_ok=True)
    path = os.path.join(responses_dir, f"{response['request']}.response.json")
    with open(path, "w") as f:
        json.dump(response, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(response, sort_keys=True))


def serve(
    requests_dir: Optional[str],
    responses_dir: str,
    use_stdin: bool = False,
    once: bool = False,
    poll_s: float = 0.5,
    devices: Optional[Any] = None,
    chunk_scenarios: Optional[int] = None,
) -> List[dict]:
    """The CLI loop: poll ``requests_dir`` for new ``*.json`` specs (and/or
    read stdin lines: a spec path, or inline spec JSON), interleave all
    active grids, write one response JSON per request. ``once`` exits when
    the queue is empty (after ingesting whatever is already there)."""
    service = SimService(
        out_root=os.path.join(responses_dir, "out"),
        devices=devices,
        chunk_scenarios=chunk_scenarios,
    )
    seen = set()
    n_stdin = 0
    all_responses: List[dict] = []
    stdin_open = use_stdin

    def ingest_dir():
        if not requests_dir or not os.path.isdir(requests_dir):
            return
        for fname in sorted(os.listdir(requests_dir)):
            if not fname.endswith(".json") or fname in seen:
                continue
            seen.add(fname)
            with open(os.path.join(requests_dir, fname)) as f:
                text = f.read()
            _submit(fname[: -len(".json")], text)

    def _submit(name, text):
        try:
            service.submit(name, text)
        except Exception as e:  # malformed spec -> error response, keep serving
            resp = {
                "request": name, "status": "error",
                "error": f"{type(e).__name__}: {e}",
            }
            service.responses[name] = resp
            all_responses.append(resp)
            _write_response(responses_dir, resp)

    def ingest_stdin():
        nonlocal stdin_open, n_stdin
        if not stdin_open:
            return
        line = sys.stdin.readline()
        if not line:  # EOF: no more stdin requests
            stdin_open = False
            return
        line = line.strip()
        if not line:
            return
        if line.startswith("{"):
            _submit(f"stdin-{n_stdin}", line)
            n_stdin += 1
        else:
            with open(line) as f:
                text = f.read()
            _submit(os.path.splitext(os.path.basename(line))[0], text)

    while True:
        ingest_dir()
        ingest_stdin()
        for response in service.step():
            all_responses.append(response)
            _write_response(responses_dir, response)
        if not service.active:
            if once and not stdin_open:
                break
            if not stdin_open:  # with stdin open, readline is the idle wait
                time.sleep(poll_s)
    return all_responses


def _smoke(devices: Optional[Any]) -> List[dict]:
    """Self-test (the ``make serve-smoke`` / nightly step): two queued
    same-shaped grids — the second request's sweep MUST reuse the first's
    compiled program (hits >= 1, misses == 0) because only traced operands
    (timeouts) differ between the specs."""
    import tempfile

    # start from a cold LRU so the first request's miss is observable even
    # when an earlier sweep in this process compiled the same grid shape
    engine._SWEEP_FNS.clear()
    with tempfile.TemporaryDirectory() as td:
        req = os.path.join(td, "req")
        os.makedirs(req)
        base = dict(
            workload={"preset": "fig3_small", "n_jobs": 30},
            platform=16,
            schedulers=["EASY PSUS", "FCFS PSAS"],
        )
        Experiment(name="user-a", timeouts=(60, 600), **base).save(
            os.path.join(req, "user-a.json")
        )
        Experiment(name="user-b", timeouts=(120, 1200), **base).save(
            os.path.join(req, "user-b.json")
        )
        responses = serve(
            req, os.path.join(td, "resp"), once=True, devices=devices
        )
        by_name = {r["request"]: r for r in responses}
        assert set(by_name) == {"user-a", "user-b"}, sorted(by_name)
        for r in responses:
            assert r["status"] == "done", r
            assert r["rows"] == 4, r
        a, b = by_name["user-a"], by_name["user-b"]
        assert a["compile_cache"]["misses"] >= 1, a
        assert b["compile_cache"] == {"hits": b["chunks"], "misses": 0}, (
            "second request's same-shaped grid did not reuse the compiled "
            f"program: {b}"
        )
        print("serve-smoke OK: second request hit the compile cache "
              f"({b['compile_cache']['hits']} hit(s), 0 misses)")
    return responses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", default=None, metavar="DIR",
                    help="directory polled for Experiment spec *.json files")
    ap.add_argument("--responses", default="out/sim_serve", metavar="DIR",
                    help="response JSONs (+ default per-request out dirs)")
    ap.add_argument("--stdin", action="store_true",
                    help="also read requests from stdin (one spec path or "
                         "inline spec JSON per line)")
    ap.add_argument("--once", action="store_true",
                    help="drain the queue and exit instead of watching")
    ap.add_argument("--poll", type=float, default=0.5, metavar="S",
                    help="request-directory poll interval when idle")
    ap.add_argument("--devices", default=None,
                    help='shard each launch across local devices: an int or '
                         '"all" (default: unsharded)')
    ap.add_argument("--chunk", type=int, default=None, metavar="K",
                    help="scenarios per streamed launch (default: whole grid)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the two-request compile-cache self-test and exit")
    args = ap.parse_args(argv)
    devices = (
        None if args.devices is None
        else args.devices if args.devices == "all"
        else int(args.devices)
    )
    if args.smoke:
        return _smoke(devices)
    if not args.requests and not args.stdin:
        ap.error("need --requests DIR and/or --stdin (or --smoke)")
    return serve(
        args.requests,
        args.responses,
        use_stdin=args.stdin,
        once=args.once,
        poll_s=args.poll,
        devices=devices,
        chunk_scenarios=args.chunk,
    )


if __name__ == "__main__":
    main()
