"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s per link
