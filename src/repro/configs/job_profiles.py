"""Arch × shape -> HPC job profiles: the loop-closer between the LM
substrate and the simulator (DESIGN.md §4).

SPARS schedules HPC jobs; the canonical 2025+ HPC job is large-model
training/serving. Each assigned (architecture × input shape) cell becomes a
job profile whose resource request and runtime are DERIVED from the same
numbers the dry-run produces:

    nodes    = chips needed / chips-per-node (v5e: 8 chips/host)
    runtime  = steps x roofline_step_s   (from out/dryrun when present,
               else the analytic 6·N·D / (chips x peak x assumed-MFU))

``profile_workload`` emits a Workload whose jobs are draws over these
profiles — so scheduler/PSM policies are evaluated against a realistic
mix of LM training and serving jobs rather than synthetic lognormals.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs import get_arch, list_archs
from repro.launch.mesh import PEAK_FLOPS_BF16
from repro.launch.shapes import SHAPE_SETS, applicable
from repro.workloads.workload import Job, Workload

CHIPS_PER_NODE = 8  # v5e host
DEFAULT_CHIPS = 256  # single-pod mesh
ASSUMED_MFU = 0.4


@dataclasses.dataclass(frozen=True)
class JobProfile:
    name: str  # "<arch>:<shape>"
    nodes: int
    runtime_s: int  # one workload unit (e.g. 1000 train steps / a serve shift)
    kind: str


def _param_count(arch: str) -> float:
    # avoids jax.eval_shape cost: analytic count from the config
    cfg = get_arch(arch)
    d, v = cfg.d_model, cfg.padded_vocab
    per_layer = 4 * d * d + 3 * d * max(cfg.d_ff, 1)
    if cfg.n_experts:
        per_layer = 4 * d * d + 3 * d * cfg.expert_d_ff * cfg.n_experts
    return 2 * v * d + cfg.n_layers * per_layer


def _dryrun_step_s(arch: str, shape: str, out_dir: str) -> Optional[float]:
    path = os.path.join(out_dir, f"{arch}__{shape}__single.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return None
    return rec.get("roofline", {}).get("roofline_step_s")


def build_profiles(
    chips: int = DEFAULT_CHIPS,
    steps_per_job: int = 1000,
    out_dir: str = "out/dryrun",
) -> List[JobProfile]:
    profiles = []
    for arch in list_archs():
        cfg = get_arch(arch)
        for shape_name, shape in SHAPE_SETS.items():
            if not applicable(cfg, shape)[0]:
                continue
            step_s = _dryrun_step_s(arch, shape_name, out_dir)
            if step_s is None:
                n = _param_count(arch)
                tokens = shape.batch * shape.seq
                flops = 6.0 * n * tokens if shape.kind == "train" else 2.0 * n * shape.batch
                step_s = flops / (chips * PEAK_FLOPS_BF16 * ASSUMED_MFU)
            runtime = max(60, int(steps_per_job * step_s))
            profiles.append(
                JobProfile(
                    name=f"{arch}:{shape_name}",
                    nodes=chips // CHIPS_PER_NODE,
                    runtime_s=runtime,
                    kind=shape.kind,
                )
            )
    return profiles


def profile_workload(
    n_jobs: int = 200,
    nb_nodes: int = 128,
    mean_interarrival: float = 1200.0,
    seed: int = 0,
    profiles: Optional[Sequence[JobProfile]] = None,
    overreq_factor: float = 1.5,
) -> Workload:
    """Workload whose jobs are (scaled-down) draws over the arch profiles."""
    profs = list(profiles or build_profiles())
    rng = np.random.default_rng(seed)
    inter = rng.exponential(mean_interarrival, size=n_jobs)
    subtime = np.floor(np.cumsum(inter)).astype(np.int64)
    subtime[0] = 0
    jobs = []
    for i in range(n_jobs):
        p = profs[int(rng.integers(0, len(profs)))]
        # scale node request into the platform (profiles assume a full pod)
        res = max(1, min(nb_nodes, int(p.nodes * nb_nodes / 32)))
        runtime = max(60, int(p.runtime_s * rng.lognormal(0.0, 0.3)))
        jobs.append(
            Job(
                job_id=i,
                res=res,
                subtime=int(subtime[i]),
                reqtime=int(runtime * overreq_factor),
                runtime=runtime,
                profile=p.name,
            )
        )
    return Workload(nb_res=nb_nodes, jobs=tuple(jobs)).sorted_by_subtime()
