"""stablelm-3b [dense] [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
        source="[hf:stabilityai/stablelm-2-1_6b; unverified]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
