"""ArchConfig dataclass + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

BlockSpec = Tuple[str, int]  # (block_type, count)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype_name: str = "bfloat16"
    stages: Tuple[BlockSpec, ...] = ()
    # moe
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    mamba_per_super: int = 6  # zamba2: mamba blocks per shared-attn application
    # enc-dec / modality-frontend stubs
    encoder_layers: int = 0
    encoder_seq: int = 0  # whisper: 1500 precomputed frame embeddings
    n_image_embeds: int = 0  # internvl2: prepended patch embeddings
    # runtime / distribution
    sub_quadratic: bool = False  # eligible for long_500k
    fsdp: bool = False  # additionally shard params over the data axis
    # training sharding strategy:
    #   "tp"      Megatron tensor parallel over the model axis (default)
    #   "fsdp_sp" ZeRO-3 weights over (data x model) + sequence-parallel
    #             activations over the model axis — the beyond-paper layout
    #             that wins for activation-AR-bound dense archs
    #             (EXPERIMENTS.md §Perf iteration 3)
    sharding_mode: str = "tp"
    # mesh axes carrying the batch dim (set by the launcher/dry-run);
    # used by layers whose index computations hide the batch parallelism
    # from GSPMD (MoE dispatch — see moe.moe_apply)
    batch_axes: Tuple[str, ...] = ()
    optimizer: str = "adamw"
    remat: bool = True
    # "full": recompute everything in backward; "save_tp": additionally save
    # the post-TP-reduce block outputs (checkpoint_name'd "tp_out") so the
    # remat replay does not re-run the tensor-parallel all-reduces
    # (EXPERIMENTS.md §Perf iteration 2; costs 2 x B x S x D bf16 per layer)
    remat_policy: str = "full"
    gla_chunk: int = 128
    attn_chunk: int = 1024
    vocab_pad_to: int = 256
    source: str = ""  # provenance note ([source; verified-tier])

    # ---- derived ----
    @property
    def dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype_name]

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    def block_program(self) -> Tuple[BlockSpec, ...]:
        """Decoder stage list; default derived from family when not given."""
        if self.stages:
            return self.stages
        if self.family == "moe":
            return (("moe", self.n_layers),)
        if self.family == "hybrid":
            n_super = self.n_layers // self.mamba_per_super
            return (("zamba_super", n_super),)
        if self.family == "ssm":
            return (("xlstm_pair", self.n_layers // 2),)
        if self.family == "audio":
            return (("dec", self.n_layers),)
        return (("dense", self.n_layers),)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


ARCH_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}
REDUCED_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}

_ARCH_MODULES = {
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "glm4-9b": "repro.configs.glm4_9b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "internlm2-1.8b": "repro.configs.internlm2_1p8b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}


def register(full: Callable[[], ArchConfig], reduced: Callable[[], ArchConfig]):
    cfg = full()
    ARCH_REGISTRY[cfg.name] = full
    REDUCED_REGISTRY[cfg.name] = reduced
    return cfg


def get_arch(name: str, reduced: bool = False) -> ArchConfig:
    if name not in ARCH_REGISTRY:
        if name in _ARCH_MODULES:
            importlib.import_module(_ARCH_MODULES[name])
        else:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return (REDUCED_REGISTRY if reduced else ARCH_REGISTRY)[name]()


def list_archs():
    return sorted(_ARCH_MODULES)
