"""qwen3-14b [dense] — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=17408,
        vocab_size=151936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        source="[hf:Qwen/Qwen3-8B; hf]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        head_dim=16,
        qk_norm=True,
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
