"""Architecture & experiment configs.

``get_arch(name)`` returns the full assigned config; ``get_arch(name,
reduced=True)`` returns the CPU-smoke-test reduction of the same family.
"""
from repro.configs.base import ArchConfig, ARCH_REGISTRY, get_arch, list_archs

__all__ = ["ArchConfig", "ARCH_REGISTRY", "get_arch", "list_archs"]
