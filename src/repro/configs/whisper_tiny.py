"""whisper-tiny [audio] — enc-dec, conv frontend stub [arXiv:2212.04356;
unverified]. The conv/mel frontend is a STUB per the assignment:
``input_specs()`` provides 1500 precomputed frame embeddings; encoder (4L,
bidirectional) and decoder (4L, causal + cross-attention) are fully modeled.
Decoder self-attention uses RoPE (adaptation: whisper's learned positional
embeddings cap at 448 positions, incompatible with the assigned 32k decode
shapes — recorded in DESIGN.md)."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        encoder_layers=4,
        encoder_seq=1500,
        source="[arXiv:2212.04356; unverified]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        encoder_layers=2,
        encoder_seq=30,
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
