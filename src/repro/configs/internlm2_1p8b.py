"""internlm2-1.8b [dense] — GQA kv=8 [arXiv:2403.17297; hf]."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        source="[arXiv:2403.17297; hf]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
