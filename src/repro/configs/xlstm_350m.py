"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks
[arXiv:2405.04517; unverified]. d_ff=0: the recurrent blocks carry their own
up/down projections (no separate FFN)."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        ssm_expand=2,
        sub_quadratic=True,
        source="[arXiv:2405.04517; unverified]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        ssm_expand=2,
        sub_quadratic=True,
        dtype_name="float32",
        gla_chunk=16,
    )


CONFIG = register(full, reduced)
