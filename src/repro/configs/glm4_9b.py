"""glm4-9b [dense] — RoPE, GQA kv=2 [hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        source="[hf:THUDM/glm-4-9b; hf]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
