"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].
FSDP + Adafactor by default: bf16 weights alone are 628 GB, so parameters and
optimizer state shard over (data x model) jointly."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        n_experts=8,
        top_k=2,
        expert_d_ff=32768,
        n_shared_experts=0,
        shared_d_ff=0,
        fsdp=True,
        optimizer="adafactor",
        source="[hf:xai-org/grok-1; unverified]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        n_experts=4,
        top_k=2,
        expert_d_ff=128,
        n_shared_experts=0,
        shared_d_ff=0,
        optimizer="adafactor",
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
