"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. expert d_ff=1408; shared-expert hidden
= 4 x 1408 = 5632."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        n_experts=60,
        top_k=4,
        expert_d_ff=1408,
        n_shared_experts=4,
        shared_d_ff=5632,
        source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab_size=512,
        n_experts=8,
        top_k=2,
        expert_d_ff=32,
        n_shared_experts=2,
        shared_d_ff=64,
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
