"""zamba2-2.7b [hybrid] — Mamba2 + shared attention blocks
[arXiv:2411.15242; hf]. 54 Mamba-2 layers grouped into 9 super-blocks of 6,
each followed by one application of a weight-tied shared attention block."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        mamba_per_super=6,
        sub_quadratic=True,
        source="[arXiv:2411.15242; hf]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        mamba_per_super=2,
        sub_quadratic=True,
        dtype_name="float32",
        gla_chunk=16,
    )


CONFIG = register(full, reduced)
