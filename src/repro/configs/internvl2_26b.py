"""internvl2-26b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].
The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (256 tokens) merged at the sequence head; the
transformer backbone (InternLM2-20B-class) is fully modeled."""
from repro.configs.base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        n_image_embeds=256,
        # fsdp=False + adafactor (EXPERIMENTS.md §Perf iteration 8): the
        # dense FSDP layout made GSPMD replicate activations over the data
        # axis (ratio 0.26, 4.35x step inflation); TP-only with a factored
        # optimizer fits the 26B params in HBM without it
        fsdp=False,
        optimizer="adafactor",
        source="[arXiv:2404.16821; hf]",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        n_image_embeds=8,
        dtype_name="float32",
    )


CONFIG = register(full, reduced)
