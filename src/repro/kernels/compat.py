"""Pallas API compatibility across JAX versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; resolve whichever name the installed version provides so the
kernels import on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

def _unsupported(*args, **kwargs):
    raise ImportError(
        "jax.experimental.pallas.tpu provides neither CompilerParams nor "
        "TPUCompilerParams; this JAX version is unsupported by the Pallas "
        "kernels"
    )


CompilerParams = (
    getattr(pltpu, "CompilerParams", None)
    or getattr(pltpu, "TPUCompilerParams", None)
    or _unsupported
)

__all__ = ["CompilerParams"]
