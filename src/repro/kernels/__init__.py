"""Pallas TPU kernels for the framework's compute hot-spots.

    flash_attention   GQA flash attention (LM training/prefill hot-spot)
    ssd_scan          chunked SSD/GLA scan (Mamba-2 / mLSTM core)
    event_fuse        fused event-batch reduction (vmapped SPARS engine)

Each kernel ships with a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the
jit'd wrappers (interpret=True on CPU hosts). The XLA twins used by the
model stack live next to their layers (``layers.attention_chunked``,
``ssm.chunked_gla``) so the models compile on any backend; the Pallas
versions are the TPU production path.
"""
from repro.kernels.ops import event_fuse, flash_attention, ssd_scan

__all__ = ["flash_attention", "ssd_scan", "event_fuse"]
