"""Pallas-TPU chunked SSD/GLA scan — the Mamba-2 / mLSTM training core.

Implements the gated-linear-attention recurrence

    h_t = exp(g_t) · h_{t-1} + k_t ⊗ v_t
    y_t = q_t · h_t

in the chunk-parallel "state-space duality" form: within a chunk the output
is a masked decay-weighted (Q·Kᵀ)·V product (two MXU matmuls), and only the
O(S/chunk) inter-chunk state pass is sequential. Grid is
``(B, H, S/chunk)`` with the chunk dimension innermost and ``arbitrary``
semantics; the running state ``h ∈ [dk, dv]`` (f32) lives in VMEM scratch
and is carried across chunk steps — the sequential dependency never leaves
the core.

Block shapes (per grid step):

    q/k (1, 1, C, dk), v (1, 1, C, dv), g (1, 1, C, 1)   C = chunk
    y   (1, 1, C, dv)                                      written per step
    hT  (1, 1, dk, dv)                                     final state, written
                                                            at the last step

VMEM working set: C·(2dk+2dv) + C² (decay matrix) + dk·dv floats — with
C=128, dk=dv=128 that's ~190 KiB. All decays g ≤ 0, so every exponential in
the chunk program is ≤ 1 and no max-stabilizer bookkeeping is needed
(numerics note in ssm.py).

Oracle: ``ref.gla_reference`` (sequential scan) and the XLA twin
``repro.models.ssm.chunked_gla``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(
    q_ref,  # (1, 1, C, dk)
    k_ref,  # (1, 1, C, dk)
    v_ref,  # (1, 1, C, dv)
    g_ref,  # (1, 1, C, 1)
    y_ref,  # (1, 1, C, dv)
    hT_ref,  # (1, 1, dk, dv)
    h_ref,  # (dk, dv) f32 scratch — inter-chunk state
    *,
    chunk: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (C, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    g = g_ref[0, 0].astype(jnp.float32)  # (C, 1) log-decay per step

    bcum = jnp.cumsum(g, axis=0)  # (C, 1) inclusive decay from chunk start
    b_end = bcum[chunk - 1 :, :]  # (1, 1) total chunk decay

    # intra-chunk: y[t] = sum_{s<=t} exp(b_t - b_s) (q_t . k_s) v_s
    diff = bcum - bcum.reshape(1, chunk)  # (C, C): b_t - b_s
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(t_idx >= s_idx, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C)
    y_intra = jax.lax.dot_general(
        scores * decay, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (C, dv)

    # inter-chunk: y[t] += exp(b_t) q_t . h_in
    h_in = h_ref[...]
    y_inter = jax.lax.dot_general(
        q * jnp.exp(bcum), h_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(b_end) h_in + sum_s exp(b_end - b_s) k_s v_s
    k_scaled = k * jnp.exp(b_end - bcum)  # (C, dk)
    h_new = jnp.exp(b_end[0, 0]) * h_in + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h_ref[...] = h_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        hT_ref[0, 0] = h_new


def ssd_scan(
    q: jax.Array,  # [B, S, H, dk]
    k: jax.Array,  # [B, S, H, dk]
    v: jax.Array,  # [B, S, H, dv]
    g: jax.Array,  # [B, S, H] log-decay (<= 0)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel GLA scan. Returns (y [B,S,H,dv], h_final [B,H,dk,dv])."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    qt = jnp.moveaxis(q, 2, 1)  # [B, H, S, dk]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    gt = jnp.moveaxis(g, 2, 1)[..., None]  # [B, H, S, 1]

    grid = (b, h, n_chunks)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, ci: (b_, h_, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dv), v.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt, gt)
    return jnp.moveaxis(y, 1, 2), hT
