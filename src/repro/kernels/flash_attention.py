"""Pallas-TPU flash attention (forward) with GQA and causal masking.

Grid ``(B, H, Sq/bq, Sk/bk)`` with the KV dimension innermost and
``arbitrary`` (sequential) semantics; the online-softmax running state
(acc, m, l) lives in VMEM scratch and is carried across KV steps. Block
shapes are explicit BlockSpecs:

    q   (1, 1, bq, hd)   indexed (b, h, qi)          — revisited per kv step
    k/v (1, 1, bk, hd)   indexed (b, h // n_rep, ki) — GQA: query heads in the
                                                        same group share a KV
                                                        block, no materialized
                                                        repeat_kv
    out (1, 1, bq, hd)   written at the last kv step

VMEM working set per core = bq·hd (q) + 2·bk·hd (kv) + bq·hd (acc)
+ 2·bq·128 (m, l) floats — with bq=bk=128, hd=128 that is ~200 KiB, far
under the ~16 MiB v5e VMEM budget, leaving room for Mosaic's double
buffering of the kv stream. MXU alignment: bq/bk multiples of 128; hd is
the lane dim (128-aligned for the assigned archs' 128-dim heads; 64/80-dim
heads pad lanes, noted in DESIGN.md).

Causal skipping: KV blocks strictly above the diagonal are skipped via
``pl.when`` (no FLOPs, no VMEM writes), halving work for causal attention.

Numerics match ``ref.flash_attention_reference`` (fp32 accumulate,
exp-rescaled online softmax) to ~1e-6 in f32 / ~2e-2 in bf16.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _flash_kernel(
    q_ref,  # (1, 1, bq, hd)
    k_ref,  # (1, 1, bk, hd)
    v_ref,  # (1, 1, bk, hd)
    o_ref,  # (1, 1, bq, hd)
    acc_ref,  # (bq, hd) f32 scratch
    m_ref,  # (bq, LANES) f32 scratch
    l_ref,  # (bq, LANES) f32 scratch
    *,
    causal: bool,
    sm_scale: float,
    block_q: int,
    block_k: int,
    kv_steps: int,
    kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip kv blocks strictly above the diagonal
    q_lo = qi * block_q
    k_lo = ki * block_k
    should_run = jnp.logical_or(
        jnp.logical_not(causal), k_lo <= q_lo + block_q - 1
    )

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale  # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len  # tail padding
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, KH, hd]
    v: jax.Array,  # [B, Sk, KH, hd]
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention forward. Returns [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    assert h % kh == 0, "query heads must be a multiple of kv heads"
    n_rep = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0, (sq, block_q)
    kv_steps = pl.cdiv(sk, block_k)
    sk_pad = kv_steps * block_k

    # [B, H, S, hd] layout: heads become grid dims, S x hd are the VMEM tiles
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if sk_pad != sk:
        pad = ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0))
        kt = jnp.pad(kt, pad)
        vt = jnp.pad(vt, pad)

    grid = (b, h, sq // block_q, kv_steps)
    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        kv_steps=kv_steps,
        kv_len=sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b_, h_, qi, ki, n_rep=n_rep: (b_, h_ // n_rep, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b_, h_, qi, ki, n_rep=n_rep: (b_, h_ // n_rep, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)  # [B, Sq, H, hd]
