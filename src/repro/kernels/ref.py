"""Pure-jnp oracles for every Pallas kernel in this package.

Each reference implements the kernel's mathematical contract with no tiling
or VMEM concerns; kernel tests sweep shapes/dtypes and assert_allclose
against these (interpret=True on CPU).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import INF_TIME, N_STATES, SWITCHING_OFF, SWITCHING_ON


def flash_attention_reference(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, KH, hd]
    v: jax.Array,  # [B, Sk, KH, hd]
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Materialized-scores GQA attention, fp32 softmax."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    n_rep = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    k = jnp.repeat(k, n_rep, axis=2)
    v = jnp.repeat(v, n_rep, axis=2)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * sm_scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gla_reference(
    q: jax.Array,  # [B, S, H, dk]
    k: jax.Array,
    v: jax.Array,  # [B, S, H, dv]
    g: jax.Array,  # [B, S, H] log-decay
) -> Tuple[jax.Array, jax.Array]:
    """Sequential GLA recurrence oracle. Returns (y, h_final)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    h0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(hst, xs):
        qt, kt, vt, gt = xs
        hst = jnp.exp(gt.astype(jnp.float32))[..., None, None] * hst + jnp.einsum(
            "bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32)
        )
        yt = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), hst)
        return hst, yt

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, g))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), hT


def event_fuse_reference(
    node_state: jax.Array,  # [E, N] i32
    node_until: jax.Array,  # [E, N] i32
    t: jax.Array,  # [E] i32
    power: jax.Array,  # [5] f32
) -> Tuple[jax.Array, jax.Array]:
    """(power_draw [E] f32, next strictly-future transition [E] i32)."""
    draw = jnp.sum(power[node_state], axis=1)
    switching = (node_state == SWITCHING_ON) | (node_state == SWITCHING_OFF)
    future = node_until > t[:, None]
    masked = jnp.where(switching & future, node_until, jnp.int32(INF_TIME))
    return draw.astype(jnp.float32), jnp.min(masked, axis=1)


def event_fuse_occ_reference(
    node_state: jax.Array,  # [E, N] i32
    node_until: jax.Array,  # [E, N] i32
    t: jax.Array,  # [E] i32
    group_id: jax.Array,  # [N] i32
    n_groups: int,
) -> Tuple[jax.Array, jax.Array]:
    """(occupancy counts [E, G, 8] f32, next transition [E] i32).

    ``occ[e, g, s] = count(group == g and state == s)`` for the 5 live
    states; columns 5..7 of each group row are zero.
    """
    comb = group_id[None, :] * 8 + node_state  # [E, N]
    onehot = comb[:, :, None] == jnp.arange(
        n_groups * 8, dtype=node_state.dtype
    )
    occ = jnp.sum(onehot.astype(jnp.float32), axis=1)
    switching = (node_state == SWITCHING_ON) | (node_state == SWITCHING_OFF)
    future = node_until > t[:, None]
    masked = jnp.where(switching & future, node_until, jnp.int32(INF_TIME))
    e = node_state.shape[0]
    return occ.reshape(e, n_groups, 8), jnp.min(masked, axis=1)


def event_fuse_ledger_reference(
    node_state: jax.Array,  # [E, N] i32
    node_until: jax.Array,  # [E, N] i32
    t: jax.Array,  # [E] i32
    power: jax.Array,  # [5] f32
) -> Tuple[jax.Array, jax.Array]:
    """(per-state power sums [E, 8] f32, next transition [E] i32).

    ``sums[e, s] = count(state == s) * power[s]`` for the 5 live states;
    columns 5..7 (including the kernel's PAD_STATE) are zero.
    """
    power8 = jnp.zeros(8, jnp.float32).at[:N_STATES].set(power)
    onehot = node_state[:, :, None] == jnp.arange(8, dtype=node_state.dtype)
    sums = jnp.sum(jnp.where(onehot, power8, 0.0), axis=1)
    switching = (node_state == SWITCHING_ON) | (node_state == SWITCHING_OFF)
    future = node_until > t[:, None]
    masked = jnp.where(switching & future, node_until, jnp.int32(INF_TIME))
    return sums.astype(jnp.float32), jnp.min(masked, axis=1)
