"""Pallas-TPU fused event-batch reduction for the vectorized SPARS engine.

The hot loop of the paper's system, once vmapped over thousands of RL
environments, is the per-batch pair

    accrue_energy : power_draw(t) = Σ_n power[state_n]      (histogram)
    next_time     : min over switching nodes of until_n      (masked min)

Each is a bandwidth-bound reduction over the node arrays (the engine reads
``node_state``/``node_until`` twice per event batch). This kernel fuses the
two into ONE pass over the node arrays — per env-block it reads the i32
state/until rows once from HBM into VMEM and emits both reductions:

    power_draw [E, 1] f32 : instantaneous power at time t
    next_trans [E, 1] i32 : earliest strictly-future transition completion

Grid ``(E/bE,)``; block (bE, N). N is the node count — padded to a lane
multiple (128) by the wrapper with PAD_STATE (histogram weight 0, masked out
of the min). The per-state power table is a (1, 8) VMEM operand (5 states
padded to 8) broadcast to every grid step.

Arithmetic intensity ≈ (5 compares + 5 FMAs + 1 select) per 8 bytes —
firmly memory-bound; the win over the XLA pair is the halved HBM traffic
(one read of each row instead of two), which the roofline model in
EXPERIMENTS.md §Perf quantifies for the spars-rl cell.

Oracle: ``ref.event_fuse_reference``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.types import INF_TIME, N_STATES, SWITCHING_OFF, SWITCHING_ON

PAD_STATE = 7  # padding nodes: zero power, never transitioning
LANES = 128


def _event_kernel(
    state_ref,  # (bE, N) i32
    until_ref,  # (bE, N) i32
    t_ref,  # (bE, 1) i32
    power_ref,  # (1, 8) f32
    draw_ref,  # (bE, 1) f32
    next_ref,  # (bE, 1) i32
):
    state = state_ref[...]
    until = until_ref[...]
    t = t_ref[...]  # (bE, 1)

    # --- fused histogram: power_draw = sum_n power[state_n] ---
    draw = jnp.zeros(state.shape, jnp.float32)
    for s in range(N_STATES):
        draw = draw + jnp.where(state == s, power_ref[0, s], 0.0)
    draw_ref[...] = jnp.sum(draw, axis=1, keepdims=True)

    # --- fused masked min: next strictly-future transition completion ---
    switching = jnp.logical_or(state == SWITCHING_ON, state == SWITCHING_OFF)
    future = until > t  # (bE, N) broadcast over nodes
    masked = jnp.where(
        jnp.logical_and(switching, future), until, jnp.int32(INF_TIME)
    )
    next_ref[...] = jnp.min(masked, axis=1, keepdims=True)


def _event_ledger_kernel(
    state_ref,  # (bE, N) i32
    until_ref,  # (bE, N) i32
    t_ref,  # (bE, 1) i32
    power_ref,  # (1, 8) f32
    draw_ref,  # (bE, 8) f32 per-state power sums
    next_ref,  # (bE, 1) i32
):
    """Ledger variant: per-STATE power sums instead of the scalar total.

    The engine's energy accounting is a [G, 5] group x state ledger; on a
    single-group platform the per-state column sums ARE the ledger row, so
    this variant lets the fused pass feed ``accrue_energy`` directly. Same
    one-read-per-row structure as :func:`_event_kernel`.
    """
    state = state_ref[...]
    until = until_ref[...]
    t = t_ref[...]  # (bE, 1)

    # --- per-state histogram columns: sums[e, s] = n_s(e) * power[s] ---
    cols = [
        jnp.sum(
            jnp.where(state == s, power_ref[0, s], 0.0),
            axis=1, keepdims=True,
        )
        for s in range(N_STATES)
    ]
    zero = jnp.zeros_like(cols[0])
    draw_ref[...] = jnp.concatenate(cols + [zero] * (8 - N_STATES), axis=1)

    # --- fused masked min: next strictly-future transition completion ---
    switching = jnp.logical_or(state == SWITCHING_ON, state == SWITCHING_OFF)
    future = until > t  # (bE, N) broadcast over nodes
    masked = jnp.where(
        jnp.logical_and(switching, future), until, jnp.int32(INF_TIME)
    )
    next_ref[...] = jnp.min(masked, axis=1, keepdims=True)


def _event_occ_kernel(
    state_ref,  # (bE, N) i32
    until_ref,  # (bE, N) i32
    t_ref,  # (bE, 1) i32
    gid_ref,  # (1, N) i32 node-group index
    occ_ref,  # (bE, G*8) f32 per-(group, state) node counts
    next_ref,  # (bE, 1) i32
):
    """Grouped-ledger variant: per-(group, state) occupancy counts.

    The grouped-tables engine path (core/SEMANTICS.md §Group-indexed
    tables) accrues energy as the contraction ``occ[G, 5] · power[G, 5]``,
    so the fused pass emits the raw occupancy histogram instead of power
    sums — the watts contraction (which is mode-dependent under DVFS)
    stays in the engine. Group and state are fused into one comparison
    key ``gid * 8 + state`` so each (g, s) cell costs one compare + one
    row sum; padding columns carry ``gid=0, state=PAD_STATE`` and land in
    cell (0, 7), which the wrapper slices off. Same one-read-per-row
    structure and masked next-transition min as :func:`_event_kernel`.
    """
    state = state_ref[...]
    until = until_ref[...]
    t = t_ref[...]  # (bE, 1)
    comb = gid_ref[...] * 8 + state  # (bE, N) via broadcast

    n_cells = occ_ref.shape[1]  # G*8, static
    cols = [
        jnp.sum(
            jnp.where(comb == c, 1.0, 0.0).astype(jnp.float32),
            axis=1, keepdims=True,
        )
        for c in range(n_cells)
    ]
    occ_ref[...] = jnp.concatenate(cols, axis=1)

    # --- fused masked min: next strictly-future transition completion ---
    switching = jnp.logical_or(state == SWITCHING_ON, state == SWITCHING_OFF)
    future = until > t  # (bE, N) broadcast over nodes
    masked = jnp.where(
        jnp.logical_and(switching, future), until, jnp.int32(INF_TIME)
    )
    next_ref[...] = jnp.min(masked, axis=1, keepdims=True)


def _pad_inputs(node_state, node_until, t, power, block_e):
    """Pad (E, N) operands to the kernel's tile grid; PAD_STATE rows/cols
    have zero histogram weight and until=INF (masked out of the min)."""
    e, n = node_state.shape
    n_pad = pl.cdiv(n, LANES) * LANES
    e_pad = pl.cdiv(e, block_e) * block_e
    if n_pad != n or e_pad != e:
        node_state = jnp.pad(
            node_state, ((0, e_pad - e), (0, n_pad - n)),
            constant_values=PAD_STATE,
        )
        node_until = jnp.pad(
            node_until, ((0, e_pad - e), (0, n_pad - n)),
            constant_values=int(INF_TIME),
        )
    t2 = jnp.pad(t[:, None], ((0, e_pad - e), (0, 0)))
    power8 = jnp.zeros((1, 8), jnp.float32).at[0, :N_STATES].set(power)
    return node_state, node_until, t2, power8, e_pad, n_pad


def event_fuse(
    node_state: jax.Array,  # [E, N] i32
    node_until: jax.Array,  # [E, N] i32
    t: jax.Array,  # [E] i32
    power: jax.Array,  # [5] f32
    *,
    block_e: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (power_draw [E], next_transition [E]) over vmapped envs."""
    e, n = node_state.shape
    node_state, node_until, t2, power8, e_pad, n_pad = _pad_inputs(
        node_state, node_until, t, power, block_e
    )
    grid = (e_pad // block_e,)
    draw, nxt = pl.pallas_call(
        _event_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_e, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_e, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_e, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_e, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((e_pad, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(node_state, node_until, t2, power8)
    return draw[:e, 0], nxt[:e, 0]


def event_fuse_occ(
    node_state: jax.Array,  # [E, N] i32
    node_until: jax.Array,  # [E, N] i32
    t: jax.Array,  # [E] i32
    group_id: jax.Array,  # [N] i32
    n_groups: int,
    *,
    block_e: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (occupancy [E, G, 8] f32, next_transition [E]) — grouped path.

    Live states occupy columns 0..4 of each group row; columns 5..7 are
    zero (PAD_STATE contamination from lane padding lands in cell
    ``(0, 7)`` and is zeroed below, matching the jnp reference).
    """
    e, n = node_state.shape
    n_pad = pl.cdiv(n, LANES) * LANES
    e_pad = pl.cdiv(e, block_e) * block_e
    if n_pad != n or e_pad != e:
        node_state = jnp.pad(
            node_state, ((0, e_pad - e), (0, n_pad - n)),
            constant_values=PAD_STATE,
        )
        node_until = jnp.pad(
            node_until, ((0, e_pad - e), (0, n_pad - n)),
            constant_values=int(INF_TIME),
        )
    t2 = jnp.pad(t[:, None], ((0, e_pad - e), (0, 0)))
    gid2 = jnp.pad(group_id[None, :], ((0, 0), (0, n_pad - n)))
    grid = (e_pad // block_e,)
    occ, nxt = pl.pallas_call(
        _event_occ_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_e, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_e, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_e, n_groups * 8), lambda i: (i, 0)),
            pl.BlockSpec((block_e, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e_pad, n_groups * 8), jnp.float32),
            jax.ShapeDtypeStruct((e_pad, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(node_state, node_until, t2, gid2)
    occ = occ[:e].reshape(e, n_groups, 8)
    if n_pad != n:  # pad lanes counted into the dead cell (0, PAD_STATE)
        occ = occ.at[:, 0, PAD_STATE].set(0.0)
    return occ, nxt[:e, 0]


def event_fuse_ledger(
    node_state: jax.Array,  # [E, N] i32
    node_until: jax.Array,  # [E, N] i32
    t: jax.Array,  # [E] i32
    power: jax.Array,  # [5] f32
    *,
    block_e: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (per-state power sums [E, 8], next_transition [E])."""
    e, n = node_state.shape
    node_state, node_until, t2, power8, e_pad, n_pad = _pad_inputs(
        node_state, node_until, t, power, block_e
    )
    grid = (e_pad // block_e,)
    draw, nxt = pl.pallas_call(
        _event_ledger_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_e, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_e, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_e, 8), lambda i: (i, 0)),
            pl.BlockSpec((block_e, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e_pad, 8), jnp.float32),
            jax.ShapeDtypeStruct((e_pad, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(node_state, node_until, t2, power8)
    return draw[:e], nxt[:e, 0]
