"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (this container) and False on
TPU, so the same call sites work in tests and production. The wrappers fall
back to the jnp reference for shapes the kernels don't tile (e.g. ragged
sequence lengths) — callers never need to special-case.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.event_fuse import event_fuse as _event_fuse_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True, sm_scale: Optional[float] = None,
    block_q: int = 128, block_k: int = 128, interpret: Optional[bool] = None,
):
    """[B,Sq,H,hd] x [B,Sk,KH,hd]^2 -> [B,Sq,H,hd]."""
    if interpret is None:
        interpret = _on_cpu()
    sq = q.shape[1]
    if sq % min(block_q, sq) != 0 or q.shape[2] % k.shape[2] != 0:
        return ref.flash_attention_reference(q, k, v, causal=causal, sm_scale=sm_scale)
    return _flash_kernel(
        q, k, v, causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    q, k, v, g, *, chunk: int = 128, interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked GLA scan: [B,S,H,dk] x2, [B,S,H,dv], [B,S,H] -> (y, h_final)."""
    if interpret is None:
        interpret = _on_cpu()
    s = q.shape[1]
    if s % min(chunk, s) != 0:
        return ref.gla_reference(q, k, v, g)
    return _ssd_kernel(q, k, v, g, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_fuse(
    node_state, node_until, t, power, *, block_e: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (power_draw, next_transition) over vmapped simulator envs."""
    if interpret is None:
        interpret = _on_cpu()
    return _event_fuse_kernel(
        node_state, node_until, t, power, block_e=block_e, interpret=interpret
    )
