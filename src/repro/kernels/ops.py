"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (this container) and False on
TPU, so the same call sites work in tests and production. The wrappers fall
back to the jnp reference for shapes the kernels don't tile (e.g. ragged
sequence lengths) — callers never need to special-case.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import INF_TIME
from repro.kernels import ref
from repro.kernels.event_fuse import LANES
from repro.kernels.event_fuse import event_fuse as _event_fuse_kernel
from repro.kernels.event_fuse import event_fuse_ledger as _event_ledger_kernel
from repro.kernels.event_fuse import event_fuse_occ as _event_occ_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# a VMEM block is (block_e, N padded to 128 lanes) x two i32 operands; past
# ~1M elements (≈8 MiB for the pair) the kernel can't tile the full node row
# and the wrapper routes to the reference instead
_EVENT_VMEM_ELEMS = 1 << 20


def _event_untileable(e: int, n: int, block_e: int) -> bool:
    n_pad = -(-n // LANES) * LANES
    return block_e * n_pad > _EVENT_VMEM_ELEMS


@functools.partial(
    jax.jit, static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True, sm_scale: Optional[float] = None,
    block_q: int = 128, block_k: int = 128, interpret: Optional[bool] = None,
):
    """[B,Sq,H,hd] x [B,Sk,KH,hd]^2 -> [B,Sq,H,hd]."""
    if interpret is None:
        interpret = _on_cpu()
    sq = q.shape[1]
    # zero-size short-circuit: empty queries/keys produce zeros (softmax
    # over zero keys is undefined); also keeps min(block_q, sq) below from
    # dividing by zero
    if 0 in q.shape or 0 in k.shape or 0 in v.shape:
        return jnp.zeros(q.shape[:-1] + (v.shape[-1],), q.dtype)
    if sq % min(block_q, sq) != 0 or q.shape[2] % k.shape[2] != 0:
        return ref.flash_attention_reference(q, k, v, causal=causal, sm_scale=sm_scale)
    return _flash_kernel(
        q, k, v, causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    q, k, v, g, *, chunk: int = 128, interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked GLA scan: [B,S,H,dk] x2, [B,S,H,dv], [B,S,H] -> (y, h_final)."""
    if interpret is None:
        interpret = _on_cpu()
    s = q.shape[1]
    # zero-size short-circuit: an empty sequence leaves the recurrence at
    # its h0 = zeros initial state; also keeps min(chunk, s) below from
    # dividing by zero
    if 0 in q.shape or 0 in v.shape:
        b, _, h, dk = q.shape
        return (
            jnp.zeros(v.shape, v.dtype),
            jnp.zeros((b, h, dk, v.shape[-1]), jnp.float32),
        )
    if s % min(chunk, s) != 0:
        return ref.gla_reference(q, k, v, g)
    return _ssd_kernel(q, k, v, g, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_fuse(
    node_state, node_until, t, power, *, block_e: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (power_draw, next_transition) over vmapped simulator envs.

    Like ``flash_attention``/``ssd_scan``, shapes the kernel can't tile
    fall back to the jnp reference — engine call sites never special-case.
    Zero-size axes short-circuit (a min over zero nodes is INF, a sum is 0;
    the reference's ``jnp.min`` would error on an empty axis).
    """
    if interpret is None:
        interpret = _on_cpu()
    e, n = node_state.shape
    if e == 0 or n == 0:
        return (
            jnp.zeros((e,), jnp.float32),
            jnp.full((e,), int(INF_TIME), jnp.int32),
        )
    if _event_untileable(e, n, block_e):
        return ref.event_fuse_reference(node_state, node_until, t, power)
    return _event_fuse_kernel(
        node_state, node_until, t, power, block_e=block_e, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("n_groups", "block_e", "interpret")
)
def event_fuse_occ(
    node_state, node_until, t, group_id, n_groups, *, block_e: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (occupancy counts [E, G, 8], next_transition [E]).

    The grouped-tables hot-loop spelling (core/SEMANTICS.md §Group-indexed
    tables): the [E, G, 8] histogram feeds ``accrue_energy``'s
    ``occ · power`` contraction directly (live states in columns 0..4),
    lifting the ledger variant's single-group restriction. Same fallback
    contract as :func:`event_fuse`.
    """
    if interpret is None:
        interpret = _on_cpu()
    e, n = node_state.shape
    if e == 0 or n == 0:
        return (
            jnp.zeros((e, n_groups, 8), jnp.float32),
            jnp.full((e,), int(INF_TIME), jnp.int32),
        )
    if _event_untileable(e, n, block_e):
        return ref.event_fuse_occ_reference(
            node_state, node_until, t, group_id, n_groups
        )
    return _event_occ_kernel(
        node_state, node_until, t, group_id, n_groups,
        block_e=block_e, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_fuse_ledger(
    node_state, node_until, t, power, *, block_e: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (per-state power sums [E, 8], next_transition [E]).

    The engine's hot-loop spelling (core/SEMANTICS.md §Hot loop): on a
    single-group platform the per-state sums are the [G=1, 5] energy-ledger
    row. Same fallback contract as :func:`event_fuse`.
    """
    if interpret is None:
        interpret = _on_cpu()
    e, n = node_state.shape
    if e == 0 or n == 0:
        return (
            jnp.zeros((e, 8), jnp.float32),
            jnp.full((e,), int(INF_TIME), jnp.int32),
        )
    if _event_untileable(e, n, block_e):
        return ref.event_fuse_ledger_reference(node_state, node_until, t, power)
    return _event_ledger_kernel(
        node_state, node_until, t, power, block_e=block_e, interpret=interpret
    )
