"""Curie-scale SWF trace replay (paper §2.3.1: real archive traces).

:func:`repro.workloads.workload.parse_swf` materializes one ``Job`` per
line as it goes — fine for the paper's small traces, wasteful for
Parallel Workloads Archive files with 10^5..10^6 lines. This module adds
the replay layer the large-scale benchmark needs:

- :func:`iter_swf_chunks` — streaming chunked parse: columnar numpy
  arrays per chunk, never more than ``chunk_jobs`` parsed records live
  (plus one raw line); large-trace consumers can feed the arrays straight
  into ``workload_from_arrays``-style constructors without 10^6 Python
  ``Job`` objects in flight.
- :func:`read_swf` — :func:`parse_swf`-equivalent Workload assembly on
  top of the chunk iterator (both readers share the single cleaning rule
  :func:`repro.workloads.workload.swf_line_job`, so they cannot drift;
  a property test asserts equality on the ragged synthetic fixture).
- :func:`rebase_submit_times` / :func:`map_procs_to_nodes` — the two
  trace-to-simulation adaptations: archive submit times are epoch-like
  offsets (the simulator clock starts at 0), and archive ``procs``
  exceed the simulated node count for oversubscribed traces.
- :func:`replay_workload` — the one-call composition used by
  ``experiments`` specs (``"swf:<path>"``) and ``bench_curie``.
- :func:`synthesize_curie_swf` — deterministic Curie-class SWF writer
  (the container is offline; the real CEA Curie trace drops in via the
  same ``replay_workload`` call when present).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.workloads.workload import (
    Job,
    Workload,
    swf_header_maxprocs,
    swf_line_job,
)

__all__ = [
    "iter_swf_chunks",
    "read_swf",
    "rebase_submit_times",
    "map_procs_to_nodes",
    "replay_workload",
    "write_swf",
    "synthesize_curie_swf",
]

_COLS = ("job_id", "res", "subtime", "reqtime", "runtime")

OVERSIZE_POLICIES = ("clamp", "drop", "error")


def iter_swf_chunks(
    path: str,
    chunk_jobs: int = 8192,
    max_jobs: Optional[int] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Stream an SWF trace as columnar numpy chunks.

    Yields dicts with i64 arrays ``job_id/res/subtime/reqtime/runtime``
    (≤ ``chunk_jobs`` rows each, trace order). A ``"max_procs"`` key rides
    on the FIRST yielded chunk when the header carried MaxProcs — the
    header precedes all data lines in well-formed SWF, and streaming
    cannot wait for EOF to report it. Dropped/ragged/comment lines are
    skipped by the shared cleaning rule (``swf_line_job``).
    """
    if chunk_jobs <= 0:
        raise ValueError(f"chunk_jobs must be positive, got {chunk_jobs}")
    buf: List[Job] = []
    max_procs: Optional[int] = None
    first = True
    n_seen = 0

    def emit(jobs: List[Job]) -> Dict[str, np.ndarray]:
        nonlocal first
        chunk = {
            "job_id": np.array([j.job_id for j in jobs], np.int64),
            "res": np.array([j.res for j in jobs], np.int64),
            "subtime": np.array([j.subtime for j in jobs], np.int64),
            "reqtime": np.array([j.reqtime for j in jobs], np.int64),
            "runtime": np.array([j.runtime for j in jobs], np.int64),
        }
        if first and max_procs is not None:
            chunk["max_procs"] = max_procs
        first = False
        return chunk

    with open(path) as f:
        for line in f:
            mp = swf_header_maxprocs(line.strip())
            if mp is not None:
                max_procs = mp
                continue
            job = swf_line_job(line)
            if job is None:
                continue
            buf.append(job)
            n_seen += 1
            if len(buf) >= chunk_jobs:
                yield emit(buf)
                buf = []
            if max_jobs is not None and n_seen >= max_jobs:
                break
    if buf or first:
        # the final partial chunk — or an empty first chunk so even a
        # job-less trace reports its MaxProcs header
        yield emit(buf)


def read_swf(
    path: str,
    max_jobs: Optional[int] = None,
    chunk_jobs: int = 8192,
) -> Workload:
    """Streaming :func:`parse_swf` twin: same Workload, chunked parse."""
    cols: Dict[str, List[np.ndarray]] = {c: [] for c in _COLS}
    nb_res = 0
    for chunk in iter_swf_chunks(path, chunk_jobs=chunk_jobs, max_jobs=max_jobs):
        nb_res = int(chunk.get("max_procs", nb_res))
        for c in _COLS:
            cols[c].append(chunk[c])
    arr = {c: np.concatenate(cols[c]) for c in _COLS}
    jobs = tuple(
        Job(
            job_id=int(arr["job_id"][i]),
            res=int(arr["res"][i]),
            subtime=int(arr["subtime"][i]),
            reqtime=int(arr["reqtime"][i]),
            runtime=int(arr["runtime"][i]),
        )
        for i in range(len(arr["job_id"]))
    )
    if nb_res == 0:
        nb_res = max((j.res for j in jobs), default=1)
    return Workload(nb_res=nb_res, jobs=jobs).sorted_by_subtime()


def rebase_submit_times(workload: Workload) -> Workload:
    """Shift submit times so the earliest submission lands at t = 0.

    Archive traces carry epoch-like submit offsets (often starting at
    10^4..10^6 s); the simulator clock starts at 0 and i32 time leaves
    ~2^30 s of headroom, so replay always rebases. Relative spacing —
    including duplicate timestamps — is untouched.
    """
    if not workload.jobs:
        return workload
    t0 = min(j.subtime for j in workload.jobs)
    if t0 == 0:
        return workload
    return Workload(
        workload.nb_res,
        tuple(
            dataclasses.replace(j, subtime=j.subtime - t0)
            for j in workload.jobs
        ),
    )


def map_procs_to_nodes(
    workload: Workload,
    nb_nodes: int,
    procs_per_node: int = 1,
    oversize: str = "clamp",
) -> Workload:
    """Map SWF processor requests onto simulated nodes.

    ``res_nodes = ceil(res / procs_per_node)``; jobs still wider than the
    platform follow the ``oversize`` policy: ``"clamp"`` caps them at
    ``nb_nodes`` (keeps the trace's load, changes its shape), ``"drop"``
    removes them (keeps shapes, loses load), ``"error"`` refuses. The
    returned Workload's ``nb_res`` is ``nb_nodes`` — the engine sizes its
    allocation window from it.
    """
    if oversize not in OVERSIZE_POLICIES:
        raise ValueError(
            f"oversize must be one of {OVERSIZE_POLICIES}, got {oversize!r}"
        )
    if nb_nodes <= 0 or procs_per_node <= 0:
        raise ValueError(
            "nb_nodes and procs_per_node must be positive, got "
            f"{nb_nodes} and {procs_per_node}"
        )
    jobs: List[Job] = []
    for j in workload.jobs:
        res = -(-j.res // procs_per_node)
        if res > nb_nodes:
            if oversize == "drop":
                continue
            if oversize == "error":
                raise ValueError(
                    f"job {j.job_id} needs {res} nodes "
                    f"({j.res} procs / {procs_per_node} per node) on a "
                    f"{nb_nodes}-node platform; pass oversize='clamp' or "
                    "'drop' to replay anyway"
                )
            res = nb_nodes
        jobs.append(dataclasses.replace(j, res=res))
    return Workload(nb_res=nb_nodes, jobs=tuple(jobs))


def replay_workload(
    path: str,
    nb_nodes: Optional[int] = None,
    procs_per_node: int = 1,
    oversize: str = "clamp",
    max_jobs: Optional[int] = None,
    rebase: bool = True,
) -> Workload:
    """Read an SWF trace and adapt it for simulation in one call.

    ``nb_nodes=None`` sizes the platform from the trace itself
    (``ceil(MaxProcs / procs_per_node)``, falling back to the widest job).
    """
    wl = read_swf(path, max_jobs=max_jobs)
    if nb_nodes is None:
        nb_nodes = -(-wl.nb_res // procs_per_node)
    wl = map_procs_to_nodes(
        wl, nb_nodes, procs_per_node=procs_per_node, oversize=oversize
    )
    if rebase:
        wl = rebase_submit_times(wl)
    return wl.sorted_by_subtime()


def write_swf(
    workload: Workload, path: str, max_procs: Optional[int] = None
) -> None:
    """Write a Workload as a Standard Workload Format file.

    Emits the 18 standard fields with ``-1`` for the ones the simulator
    does not model, plus a MaxProcs header — round-trippable through both
    readers.
    """
    mp = int(max_procs if max_procs is not None else workload.nb_res)
    with open(path, "w") as f:
        f.write("; SWF written by repro.workloads.traces.write_swf\n")
        f.write(f"; MaxProcs: {mp}\n")
        for j in workload.sorted_by_subtime().jobs:
            fields = [
                j.job_id, j.subtime, -1, j.runtime, j.res, -1, -1,
                j.res, j.reqtime, -1, 1, j.user_id, -1, -1, -1, -1, -1, -1,
            ]
            f.write(" ".join(str(x) for x in fields) + "\n")


def synthesize_curie_swf(
    path: str, n_jobs: int = 10_000, seed: int = 1300
) -> str:
    """Write a deterministic Curie-class SWF trace and return ``path``.

    The container is offline, so the large-scale replay benchmark cannot
    fetch ``CEA-Curie-2011-2.1-cln.swf``; this synthesizes a trace with
    the ``cea_curie`` generator preset's summary statistics (11 200
    nodes, heavy-tailed runtimes, wide jobs up to 8192 procs) and writes
    it through :func:`write_swf`, exercising the full parse → map →
    rebase replay path end to end. The real trace drops into the same
    ``replay_workload`` call when present.
    """
    from repro.workloads.generator import PRESETS, generate_workload

    wl = generate_workload(PRESETS["cea_curie"], n_jobs=n_jobs, seed=seed)
    write_swf(wl, path, max_procs=wl.nb_res)
    return path
