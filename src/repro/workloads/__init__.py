"""Workload & platform modeling (paper §2.3.1).

JSON schemas mirror SPARS's ``platform.json`` / ``workload.json``; SWF traces
from the Parallel Workloads Archive are parsed by :mod:`repro.workloads.workload`.
"""
from repro.workloads.platform import (
    PlatformSpec,
    DEFAULT_PLATFORM,
    curie_platform,
    load_platform,
    make_platform,
)
from repro.workloads.workload import Job, Workload, load_workload, parse_swf
from repro.workloads.generator import generate_workload, PRESETS
from repro.workloads.traces import read_swf, replay_workload

__all__ = [
    "PlatformSpec",
    "DEFAULT_PLATFORM",
    "curie_platform",
    "load_platform",
    "make_platform",
    "Job",
    "Workload",
    "load_workload",
    "parse_swf",
    "read_swf",
    "replay_workload",
    "generate_workload",
    "PRESETS",
]
