"""Platform model — the paper's ``platform.json`` (§2.3.1, Table 3).

A platform describes the simulated HPC machine: node count, per-state power
draw, state-transition delays, and (schema-level) DVFS profiles. The paper's
illustrative configuration (Table 3) is exposed as :data:`DEFAULT_PLATFORM`:

    active 190 W · idle 190 W · sleep 9 W
    switch-on  190 W for 30 min · switch-off 9 W for 45 min

Heterogeneous machines (mixed partitions, staggered hardware generations,
big.LITTLE-style islands) are described as an ordered tuple of
:class:`NodeGroup` entries; node ids are assigned contiguously in group
order. The homogeneous case keeps the flat scalar fields, and the per-node
table accessors (:meth:`PlatformSpec.node_power_table` and friends)
broadcast them, so both cases feed the engines through one code path
(core/SEMANTICS.md §Heterogeneity).

DVFS is modelled at two levels. The *static* level predates runtime DVFS:
``dvfs_profiles`` + ``dvfs_mode`` pin one operating point for a whole run
(the engine then just uses the node's operating ``speed``). The *runtime*
level (core/SEMANTICS.md §DVFS) gives every node group a small mode table —
:meth:`NodeGroup.dvfs_modes`, or the document-level ``dvfs_profiles`` for a
homogeneous machine — of absolute ``(speed, active-watts)`` operating
points; a DVFS-enabled power policy switches each group's mode while the
simulation runs. :meth:`PlatformSpec.group_dvfs_tables` lowers the schema
to the ``[G, M]`` tables both engines consume.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# Node power-state encoding shared by the Python oracle and the JAX engine.
# Order matters: the engine indexes power/legality tables by these values.
SLEEP = 0
SWITCHING_ON = 1
IDLE = 2
ACTIVE = 3
SWITCHING_OFF = 4
N_STATES = 5

STATE_NAMES = ("sleep", "switching_on", "idle", "active", "switching_off")


@dataclasses.dataclass(frozen=True)
class DvfsProfile:
    """One DVFS operating point: active power draw (W) and absolute speed.

    Used statically (``PlatformSpec.dvfs_mode`` pins one profile for a whole
    run) and as a runtime mode-table entry (``NodeGroup.dvfs_modes`` /
    document-level ``dvfs_profiles`` — see core/SEMANTICS.md §DVFS).
    """

    name: str
    power: float
    speed: float = 1.0

    def __post_init__(self):
        if self.speed <= 0:
            raise ValueError(
                f"DvfsProfile.speed must be positive, got {self.speed}"
            )
        if self.power <= 0:
            raise ValueError(
                f"DvfsProfile.power must be positive, got {self.power}"
            )


def _validate_modes(modes, where: str) -> None:
    names = [p.name for p in modes]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate DVFS mode names in {where}: {names}")


@dataclasses.dataclass(frozen=True)
class NodeGroup:
    """A contiguous block of identical nodes inside a heterogeneous platform.

    ``speed`` is the group's operating compute speed (realized wall time of a
    job = nominal runtime / min speed over its allocated nodes — see
    core/SEMANTICS.md §Heterogeneity). ``dvfs_modes`` is the group's runtime
    DVFS mode table — absolute (speed, active-watts) operating points a
    DVFS-enabled power policy switches between at runtime (SEMANTICS.md
    §DVFS); empty means the single base operating point
    ``(speed, power_active)``.
    """

    count: int
    name: str = "default"
    power_active: float = 190.0
    power_idle: float = 190.0
    power_sleep: float = 9.0
    power_switch_on: float = 190.0
    power_switch_off: float = 9.0
    t_switch_on: int = 30 * 60
    t_switch_off: int = 45 * 60
    speed: float = 1.0
    dvfs_modes: Tuple[DvfsProfile, ...] = ()

    def __post_init__(self):
        if self.count <= 0:
            raise ValueError(f"NodeGroup.count must be positive, got {self.count}")
        if self.speed <= 0:
            raise ValueError(f"NodeGroup.speed must be positive, got {self.speed}")
        object.__setattr__(self, "dvfs_modes", tuple(self.dvfs_modes))
        _validate_modes(self.dvfs_modes, f"node group {self.name!r}")

    def power_table(self) -> Tuple[float, ...]:
        return (
            self.power_sleep,
            self.power_switch_on,
            self.power_idle,
            self.power_active,
            self.power_switch_off,
        )

    def to_json(self) -> Dict[str, Any]:
        out = {
            "name": self.name,
            "count": self.count,
            "compute_speed": self.speed,
            "states": {
                "sleep": {"power": self.power_sleep},
                "idle": {"power": self.power_idle},
                "active": {"power": self.power_active},
                "switching_on": {
                    "power": self.power_switch_on,
                    "transition_time": self.t_switch_on,
                },
                "switching_off": {
                    "power": self.power_switch_off,
                    "transition_time": self.t_switch_off,
                },
            },
        }
        if self.dvfs_modes:
            out["dvfs_modes"] = [
                dataclasses.asdict(p) for p in self.dvfs_modes
            ]
        return out


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """Hardware description of the simulated machine.

    Attributes mirror the paper's platform JSON. The scalar fields describe a
    homogeneous machine; ``node_groups`` (when non-empty) describes a
    heterogeneous one and takes precedence — the scalar fields then only act
    as defaults mirrored from the first group. Node ids run contiguously in
    group order.
    """

    nb_nodes: int
    power_active: float = 190.0
    power_idle: float = 190.0
    power_sleep: float = 9.0
    power_switch_on: float = 190.0
    power_switch_off: float = 9.0
    t_switch_on: int = 30 * 60  # seconds (paper: 30 minutes)
    t_switch_off: int = 45 * 60  # seconds (paper: 45 minutes)
    compute_speed: float = 1.0
    dvfs_profiles: tuple = ()
    dvfs_mode: Optional[str] = None
    node_groups: Tuple[NodeGroup, ...] = ()

    def __post_init__(self):
        if self.compute_speed <= 0:
            raise ValueError(
                f"compute_speed must be positive, got {self.compute_speed}"
            )
        object.__setattr__(self, "dvfs_profiles", tuple(self.dvfs_profiles))
        _validate_modes(self.dvfs_profiles, "platform dvfs_profiles")
        if self.dvfs_mode is not None:
            names = [p.name for p in self.dvfs_profiles]
            if self.dvfs_mode not in names:
                from repro.core.types import did_you_mean

                raise ValueError(
                    f"unknown DVFS mode {self.dvfs_mode!r}; this platform "
                    f"declares {names or 'no dvfs_profiles'}"
                    + did_you_mean(self.dvfs_mode, names)
                )
        if self.node_groups:
            object.__setattr__(self, "node_groups", tuple(self.node_groups))
            total = sum(g.count for g in self.node_groups)
            if total != self.nb_nodes:
                raise ValueError(
                    f"node_groups cover {total} nodes != nb_nodes {self.nb_nodes}"
                )
            # keep the legacy scalar views mirrored from the first group on
            # every construction path (not just platform_from_groups), so
            # legacy callers never see defaults that disagree with the
            # per-node tables the engines actually simulate
            g0 = self.node_groups[0]
            object.__setattr__(self, "power_active", g0.power_active)
            object.__setattr__(self, "power_idle", g0.power_idle)
            object.__setattr__(self, "power_sleep", g0.power_sleep)
            object.__setattr__(self, "power_switch_on", g0.power_switch_on)
            object.__setattr__(self, "power_switch_off", g0.power_switch_off)
            object.__setattr__(self, "t_switch_on", g0.t_switch_on)
            object.__setattr__(self, "t_switch_off", g0.t_switch_off)
            object.__setattr__(self, "compute_speed", g0.speed)

    # ---- group views -----------------------------------------------------
    @property
    def is_heterogeneous(self) -> bool:
        return len(self.node_groups) > 1

    def groups(self) -> Tuple[NodeGroup, ...]:
        """Group view; a homogeneous spec synthesizes one group from scalars."""
        if self.node_groups:
            return self.node_groups
        return (
            NodeGroup(
                count=self.nb_nodes,
                power_active=self.power_active,
                power_idle=self.power_idle,
                power_sleep=self.power_sleep,
                power_switch_on=self.power_switch_on,
                power_switch_off=self.power_switch_off,
                t_switch_on=self.t_switch_on,
                t_switch_off=self.t_switch_off,
                speed=self.speed(),
                # document-level profiles are the synthesized group's runtime
                # mode table — a homogeneous machine with dvfs_profiles can
                # run a DVFS-enabled policy directly
                dvfs_modes=self.dvfs_profiles,
            ),
        )

    def n_groups(self) -> int:
        return len(self.groups())

    def group_names(self) -> Tuple[str, ...]:
        return tuple(g.name for g in self.groups())

    # ---- per-node tables (the engines' native representation) ------------
    def node_group_id(self) -> np.ndarray:
        """i32[N] group index of every node (contiguous in group order)."""
        return np.repeat(
            np.arange(len(self.groups()), dtype=np.int32),
            [g.count for g in self.groups()],
        )

    def node_power_table(self) -> np.ndarray:
        """f32[N, 5] per-node per-state watts."""
        return np.repeat(
            np.asarray([g.power_table() for g in self.groups()], np.float32),
            [g.count for g in self.groups()],
            axis=0,
        )

    def node_t_switch_on(self) -> np.ndarray:
        """i32[N] per-node switch-on delay (s)."""
        return np.repeat(
            np.asarray([g.t_switch_on for g in self.groups()], np.int32),
            [g.count for g in self.groups()],
        )

    def node_t_switch_off(self) -> np.ndarray:
        """i32[N] per-node switch-off delay (s)."""
        return np.repeat(
            np.asarray([g.t_switch_off for g in self.groups()], np.int32),
            [g.count for g in self.groups()],
        )

    def node_speed(self) -> np.ndarray:
        """f32[N] per-node compute speed (realized runtime = work / speed)."""
        return np.repeat(
            np.asarray([g.speed for g in self.groups()], np.float32),
            [g.count for g in self.groups()],
        )

    def node_order_key(self) -> np.ndarray:
        """f32[N] allocation preference key: active watts per unit of work.

        Lower is better ("cheap/fast first"); computed in float32 so the JAX
        engine and the Python oracle order nodes identically
        (core/SEMANTICS.md §Heterogeneity).
        """
        table = self.node_power_table()
        return (table[:, ACTIVE] / self.node_speed()).astype(np.float32)

    def group_active_powers(self) -> Tuple[float, ...]:
        return tuple(g.power_active for g in self.groups())

    # ---- runtime DVFS mode tables (core/SEMANTICS.md §DVFS) ---------------
    def group_dvfs_modes(self) -> Tuple[Tuple[DvfsProfile, ...], ...]:
        """Each group's mode table, sorted ascending by speed (index 0 is
        the slowest mode — the heuristic ladder's idle point). A group with
        no declared modes gets the single base operating point."""
        out = []
        for g in self.groups():
            modes = g.dvfs_modes or (
                DvfsProfile("base", power=g.power_active, speed=g.speed),
            )
            out.append(tuple(sorted(modes, key=lambda p: (p.speed, p.name))))
        return tuple(out)

    def n_dvfs_modes(self) -> int:
        """Mode-table width M (max modes over groups; >= 1). M is a shape:
        platforms in one sweep must agree on it."""
        return max(len(t) for t in self.group_dvfs_modes())

    def group_dvfs_tables(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(f32[G, M] speed, f32[G, M] active watts, i32[G] mode counts).

        Groups with fewer than M modes pad by repeating their last (fastest)
        entry; the per-group count clamps mode selection so the padding is
        never chosen. Mode 0's entries equal the group's base (speed,
        power_active) when no modes are declared — that identity is the
        metamorphic single-mode guarantee (§DVFS).
        """
        tabs = self.group_dvfs_modes()
        G, M = len(tabs), self.n_dvfs_modes()
        speed = np.ones((G, M), np.float32)
        watts = np.zeros((G, M), np.float32)
        n = np.zeros(G, np.int32)
        for gi, t in enumerate(tabs):
            n[gi] = len(t)
            for mi in range(M):
                p = t[min(mi, len(t) - 1)]
                speed[gi, mi] = np.float32(p.speed)
                watts[gi, mi] = np.float32(p.power)
        return speed, watts, n

    # ---- legacy scalar views ---------------------------------------------
    def power_table(self):
        """Per-state power draw indexed by the state encoding above."""
        return (
            self.power_sleep,
            self.power_switch_on,
            self.power_idle,
            self.power_active,
            self.power_switch_off,
        )

    def speed(self) -> float:
        if self.dvfs_mode:
            for p in self.dvfs_profiles:
                if p.name == self.dvfs_mode:
                    return p.speed
        return self.compute_speed

    def to_json(self) -> Dict[str, Any]:
        out = {
            "nb_nodes": self.nb_nodes,
            "compute_speed": self.compute_speed,
            "dvfs_mode": self.dvfs_mode,
            "dvfs_profiles": [dataclasses.asdict(p) for p in self.dvfs_profiles],
            "states": {
                "sleep": {"power": self.power_sleep},
                "idle": {"power": self.power_idle},
                "active": {"power": self.power_active},
                "switching_on": {
                    "power": self.power_switch_on,
                    "transition_time": self.t_switch_on,
                },
                "switching_off": {
                    "power": self.power_switch_off,
                    "transition_time": self.t_switch_off,
                },
            },
            "transitions": [
                {"from": "sleep", "to": "switching_on"},
                {"from": "switching_on", "to": "idle"},
                {"from": "idle", "to": "active"},
                {"from": "active", "to": "idle"},
                {"from": "idle", "to": "switching_off"},
                {"from": "switching_off", "to": "sleep"},
            ],
        }
        if self.node_groups:
            out["node_groups"] = [g.to_json() for g in self.node_groups]
        return out

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)


def make_platform(nb_nodes: int, **kw) -> PlatformSpec:
    return PlatformSpec(nb_nodes=nb_nodes, **kw)


def platform_from_groups(groups: Sequence[NodeGroup], **kw) -> PlatformSpec:
    """Build a PlatformSpec from node groups (nb_nodes derived).

    A single group collapses to the equivalent scalar (homogeneous) spec so
    that "N identical nodes" and "one machine of N nodes" are literally the
    same object — the metamorphic guarantee tests rely on.
    """
    groups = tuple(groups)
    if not groups:
        raise ValueError("platform_from_groups needs at least one group")
    if len(groups) == 1:
        g = groups[0]
        if g.dvfs_modes:
            # the collapsed scalar spec keeps the group's runtime mode table
            # as its document-level profiles (groups() round-trips them)
            kw = {**kw, "dvfs_profiles": g.dvfs_modes}
        return PlatformSpec(
            nb_nodes=g.count,
            power_active=g.power_active,
            power_idle=g.power_idle,
            power_sleep=g.power_sleep,
            power_switch_on=g.power_switch_on,
            power_switch_off=g.power_switch_off,
            t_switch_on=g.t_switch_on,
            t_switch_off=g.t_switch_off,
            compute_speed=g.speed,
            **kw,
        )
    # __post_init__ mirrors the scalar views from the first group
    return PlatformSpec(
        nb_nodes=sum(g.count for g in groups),
        node_groups=groups,
        **kw,
    )


def _states_from_json(
    states: Mapping[str, Any], defaults: Mapping[str, float]
) -> Dict[str, Any]:
    def p(name, default):
        return float(states.get(name, {}).get("power", default))

    def t(name, default):
        return int(states.get(name, {}).get("transition_time", default))

    active = p("active", defaults["power_active"])
    return {
        "power_active": active,
        # idle inherits the document-level idle like every other state; only
        # when the document doesn't set one does it default to this entry's
        # active draw (the paper's idle==active illustrative setup)
        "power_idle": p("idle", defaults.get("power_idle", active)),
        "power_sleep": p("sleep", defaults["power_sleep"]),
        "power_switch_on": p("switching_on", defaults["power_switch_on"]),
        "power_switch_off": p("switching_off", defaults["power_switch_off"]),
        "t_switch_on": t("switching_on", defaults["t_switch_on"]),
        "t_switch_off": t("switching_off", defaults["t_switch_off"]),
    }


_DEFAULTS = {
    "power_active": 190.0,
    "power_sleep": 9.0,
    "power_switch_on": 190.0,
    "power_switch_off": 9.0,
    "t_switch_on": 1800,
    "t_switch_off": 2700,
}


def _group_from_json(
    d: Mapping[str, Any],
    defaults: Mapping[str, float],
    index: int,
    count: int,
    default_speed: float = 1.0,
) -> NodeGroup:
    fields = _states_from_json(d.get("states", {}), defaults)
    modes = tuple(
        DvfsProfile(m["name"], float(m["power"]), float(m.get("speed", 1.0)))
        for m in d.get("dvfs_modes", [])
    )
    return NodeGroup(
        count=count,
        name=str(d.get("name", f"group{index}")),
        speed=float(d.get("compute_speed", d.get("speed", default_speed))),
        dvfs_modes=modes,
        **fields,
    )


def _coalesce_nodes(entries: List[NodeGroup]) -> Tuple[NodeGroup, ...]:
    """Merge consecutive per-node entries that are identical up to the name.

    The JSON loader *preserves* per-node heterogeneity, but N identical
    entries collapse into one group of N so a homogeneous platform written
    node-by-node is indistinguishable from its scalar form (metamorphic
    guarantee; also keeps the engine's group axis small).
    """
    out: List[NodeGroup] = []
    for g in entries:
        if out and dataclasses.replace(
            out[-1], count=g.count, name=g.name
        ) == g:
            out[-1] = dataclasses.replace(
                out[-1], count=out[-1].count + g.count
            )
        else:
            out.append(g)
    return tuple(out)


def _from_json(obj: Mapping[str, Any]) -> PlatformSpec:
    profiles = tuple(
        DvfsProfile(d["name"], float(d["power"]), float(d.get("speed", 1.0)))
        for d in obj.get("dvfs_profiles", [])
    )
    top = _states_from_json(obj.get("states", {}), _DEFAULTS)
    common = dict(
        dvfs_profiles=profiles,
        dvfs_mode=obj.get("dvfs_mode"),
    )

    # document-level compute_speed is the default for every group/node entry,
    # matching the homogeneous loader's semantics
    default_speed = float(obj.get("compute_speed", 1.0))
    group_defaults = {**_DEFAULTS, **top}
    if "idle" not in obj.get("states", {}):
        # only an *explicit* document idle inherits into groups; otherwise an
        # entry's idle defaults to its own active draw (paper idle==active)
        group_defaults.pop("power_idle", None)
    groups: List[NodeGroup] = []
    if "node_groups" in obj:
        for i, d in enumerate(obj["node_groups"]):
            groups.append(
                _group_from_json(
                    d, group_defaults, i, int(d["count"]), default_speed
                )
            )
    elif "nodes" in obj:
        # per-node entries (the paper schema's per-node form) are preserved,
        # with identical neighbours coalesced into groups
        for i, d in enumerate(obj["nodes"]):
            groups.append(
                _group_from_json(
                    d, group_defaults, i, int(d.get("count", 1)),
                    default_speed,
                )
            )
        groups = list(_coalesce_nodes(groups))

    if groups:
        spec = platform_from_groups(tuple(groups), **common)
        if "nb_nodes" in obj and int(obj["nb_nodes"]) != spec.nb_nodes:
            raise ValueError(
                f"nb_nodes {obj['nb_nodes']} != nodes described {spec.nb_nodes}"
            )
        return spec

    return PlatformSpec(
        nb_nodes=int(obj["nb_nodes"]),
        compute_speed=float(obj.get("compute_speed", 1.0)),
        **top,
        **common,
    )


def load_platform(path_or_obj) -> PlatformSpec:
    """Load a platform from a JSON file path or a parsed dict.

    Accepts three schema forms: flat scalar ``states`` (homogeneous),
    ``node_groups`` (list of {name, count, states, compute_speed}), and
    ``nodes`` (one entry per node; identical neighbours are coalesced but
    distinct per-node entries are preserved — never silently collapsed).
    """
    if isinstance(path_or_obj, Mapping):
        return _from_json(path_or_obj)
    with open(path_or_obj) as f:
        return _from_json(json.load(f))


def mixed_platform_example(nb_nodes: int = 16) -> PlatformSpec:
    """Canonical 3-group heterogeneous example used by tests and benchmarks.

    fast: hot 2x-speed nodes · eco: cool 0.5x nodes · std: paper Table 3.
    Different idle/sleep watts, asymmetric transition delays, 2x/0.5x/1x
    speeds — one third of the machine each (remainder to std).
    """
    a = nb_nodes // 3
    b = nb_nodes // 3
    return platform_from_groups(
        (
            NodeGroup(count=a, name="fast", power_active=300.0,
                      power_idle=250.0, power_sleep=12.0,
                      power_switch_on=300.0, power_switch_off=12.0,
                      t_switch_on=600, t_switch_off=900, speed=2.0),
            NodeGroup(count=b, name="eco", power_active=100.0,
                      power_idle=80.0, power_sleep=4.0,
                      power_switch_on=100.0, power_switch_off=4.0,
                      t_switch_on=120, t_switch_off=180, speed=0.5),
            NodeGroup(count=nb_nodes - a - b, name="std"),
        )
    )


def dvfs_platform_example(nb_nodes: int = 16) -> PlatformSpec:
    """Canonical runtime-DVFS example: the mixed 3-group platform with a
    (slow/base/turbo) mode table on each group (core/SEMANTICS.md §DVFS).

    Mode speeds bracket each group's base speed; mode watts scale roughly
    with speed so turbo trades energy for wall time. Used by tests and
    ``benchmarks/bench_dvfs.py``.
    """

    def ladder(base_speed: float, base_watts: float) -> Tuple[DvfsProfile, ...]:
        return (
            DvfsProfile("slow", power=0.6 * base_watts, speed=0.5 * base_speed),
            DvfsProfile("base", power=base_watts, speed=base_speed),
            DvfsProfile("turbo", power=1.5 * base_watts, speed=1.5 * base_speed),
        )

    mixed = mixed_platform_example(nb_nodes)
    return platform_from_groups(
        tuple(
            dataclasses.replace(g, dvfs_modes=ladder(g.speed, g.power_active))
            for g in mixed.groups()
        )
    )


def curie_platform(nb_nodes: int = 11_200) -> PlatformSpec:
    """CEA Curie-class 3-group platform preset (the paper's large-scale
    benchmark machine: 11 200 nodes).

    Mirrors Curie's real partition structure — thin (the bulk), hybrid
    (accelerated, faster and hotter), large (fat memory nodes) — with
    paper-Table-3-class power numbers scaled per partition. Group counts
    split ~82/16/2 percent and always sum to ``nb_nodes``, so smaller
    verify-scale instances keep all three groups (minimum 3 nodes).
    G = 3 regardless of N is exactly the regime the group-indexed tables
    (core/SEMANTICS.md §Group-indexed tables) are built for.
    """
    if nb_nodes < 3:
        raise ValueError(
            f"curie_platform needs >= 3 nodes (one per partition), "
            f"got {nb_nodes}"
        )
    thin = max(1, (nb_nodes * 82) // 100)
    hybrid = max(1, (nb_nodes * 16) // 100)
    if thin + hybrid >= nb_nodes:
        thin, hybrid = nb_nodes - 2, 1
    large = nb_nodes - thin - hybrid
    return platform_from_groups(
        (
            NodeGroup(count=thin, name="thin"),
            NodeGroup(count=hybrid, name="hybrid", power_active=280.0,
                      power_idle=220.0, power_sleep=11.0,
                      power_switch_on=280.0, power_switch_off=11.0,
                      t_switch_on=20 * 60, t_switch_off=30 * 60, speed=1.6),
            NodeGroup(count=large, name="large", power_active=420.0,
                      power_idle=330.0, power_sleep=18.0,
                      power_switch_on=420.0, power_switch_off=18.0,
                      t_switch_on=45 * 60, t_switch_off=60 * 60, speed=1.2),
        )
    )


# Paper Table 3 (power model); node count chosen per workload trace.
DEFAULT_PLATFORM = PlatformSpec(nb_nodes=128)
