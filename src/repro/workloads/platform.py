"""Platform model — the paper's ``platform.json`` (§2.3.1, Table 3).

A platform describes the simulated HPC machine: node count, per-state power
draw, state-transition delays, and (schema-level) DVFS profiles. The paper's
illustrative configuration (Table 3) is exposed as :data:`DEFAULT_PLATFORM`:

    active 190 W · idle 190 W · sleep 9 W
    switch-on  190 W for 30 min · switch-off 9 W for 45 min

DVFS profiles are carried in the schema for forward compatibility (the paper
models them but does not evaluate them for lack of public traces); the engine
uses the node's default profile's ``speed`` to scale runtimes.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional

# Node power-state encoding shared by the Python oracle and the JAX engine.
# Order matters: the engine indexes power/legality tables by these values.
SLEEP = 0
SWITCHING_ON = 1
IDLE = 2
ACTIVE = 3
SWITCHING_OFF = 4
N_STATES = 5

STATE_NAMES = ("sleep", "switching_on", "idle", "active", "switching_off")


@dataclasses.dataclass(frozen=True)
class DvfsProfile:
    """One DVFS operating point: nominal power (W) and normalized speed."""

    name: str
    power: float
    speed: float = 1.0


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """Hardware description of the simulated machine.

    Attributes mirror the paper's platform JSON: every node shares the same
    power model in the illustrative setup, so the spec is homogeneous; the
    JSON loader accepts per-node entries and collapses them when identical.
    """

    nb_nodes: int
    power_active: float = 190.0
    power_idle: float = 190.0
    power_sleep: float = 9.0
    power_switch_on: float = 190.0
    power_switch_off: float = 9.0
    t_switch_on: int = 30 * 60  # seconds (paper: 30 minutes)
    t_switch_off: int = 45 * 60  # seconds (paper: 45 minutes)
    compute_speed: float = 1.0
    dvfs_profiles: tuple = ()
    dvfs_mode: Optional[str] = None

    def power_table(self):
        """Per-state power draw indexed by the state encoding above."""
        return (
            self.power_sleep,
            self.power_switch_on,
            self.power_idle,
            self.power_active,
            self.power_switch_off,
        )

    def speed(self) -> float:
        if self.dvfs_mode:
            for p in self.dvfs_profiles:
                if p.name == self.dvfs_mode:
                    return p.speed
        return self.compute_speed

    def to_json(self) -> Dict[str, Any]:
        return {
            "nb_nodes": self.nb_nodes,
            "compute_speed": self.compute_speed,
            "dvfs_mode": self.dvfs_mode,
            "dvfs_profiles": [dataclasses.asdict(p) for p in self.dvfs_profiles],
            "states": {
                "sleep": {"power": self.power_sleep},
                "idle": {"power": self.power_idle},
                "active": {"power": self.power_active},
                "switching_on": {
                    "power": self.power_switch_on,
                    "transition_time": self.t_switch_on,
                },
                "switching_off": {
                    "power": self.power_switch_off,
                    "transition_time": self.t_switch_off,
                },
            },
            "transitions": [
                {"from": "sleep", "to": "switching_on"},
                {"from": "switching_on", "to": "idle"},
                {"from": "idle", "to": "active"},
                {"from": "active", "to": "idle"},
                {"from": "idle", "to": "switching_off"},
                {"from": "switching_off", "to": "sleep"},
            ],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)


def make_platform(nb_nodes: int, **kw) -> PlatformSpec:
    return PlatformSpec(nb_nodes=nb_nodes, **kw)


def _from_json(obj: Mapping[str, Any]) -> PlatformSpec:
    states = obj.get("states", {})

    def p(name, default):
        return float(states.get(name, {}).get("power", default))

    def t(name, default):
        return int(states.get(name, {}).get("transition_time", default))

    profiles = tuple(
        DvfsProfile(d["name"], float(d["power"]), float(d.get("speed", 1.0)))
        for d in obj.get("dvfs_profiles", [])
    )
    return PlatformSpec(
        nb_nodes=int(obj["nb_nodes"]),
        power_active=p("active", 190.0),
        power_idle=p("idle", p("active", 190.0)),
        power_sleep=p("sleep", 9.0),
        power_switch_on=p("switching_on", 190.0),
        power_switch_off=p("switching_off", 9.0),
        t_switch_on=t("switching_on", 1800),
        t_switch_off=t("switching_off", 2700),
        compute_speed=float(obj.get("compute_speed", 1.0)),
        dvfs_profiles=profiles,
        dvfs_mode=obj.get("dvfs_mode"),
    )


def load_platform(path_or_obj) -> PlatformSpec:
    """Load a platform from a JSON file path or a parsed dict."""
    if isinstance(path_or_obj, Mapping):
        return _from_json(path_or_obj)
    with open(path_or_obj) as f:
        return _from_json(json.load(f))


# Paper Table 3 (power model); node count chosen per workload trace.
DEFAULT_PLATFORM = PlatformSpec(nb_nodes=128)
