"""Platform model — the paper's ``platform.json`` (§2.3.1, Table 3).

A platform describes the simulated HPC machine: node count, per-state power
draw, state-transition delays, and (schema-level) DVFS profiles. The paper's
illustrative configuration (Table 3) is exposed as :data:`DEFAULT_PLATFORM`:

    active 190 W · idle 190 W · sleep 9 W
    switch-on  190 W for 30 min · switch-off 9 W for 45 min

Heterogeneous machines (mixed partitions, staggered hardware generations,
big.LITTLE-style islands) are described as an ordered tuple of
:class:`NodeGroup` entries; node ids are assigned contiguously in group
order. The homogeneous case keeps the flat scalar fields, and the per-node
table accessors (:meth:`PlatformSpec.node_power_table` and friends)
broadcast them, so both cases feed the engines through one code path
(core/SEMANTICS.md §Heterogeneity).

DVFS profiles are carried in the schema for forward compatibility (the paper
models them but does not evaluate them for lack of public traces); the engine
uses the node's operating ``speed`` to scale realized runtimes.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# Node power-state encoding shared by the Python oracle and the JAX engine.
# Order matters: the engine indexes power/legality tables by these values.
SLEEP = 0
SWITCHING_ON = 1
IDLE = 2
ACTIVE = 3
SWITCHING_OFF = 4
N_STATES = 5

STATE_NAMES = ("sleep", "switching_on", "idle", "active", "switching_off")


@dataclasses.dataclass(frozen=True)
class DvfsProfile:
    """One DVFS operating point: nominal power (W) and normalized speed."""

    name: str
    power: float
    speed: float = 1.0

    def __post_init__(self):
        if self.speed <= 0:
            raise ValueError(
                f"DvfsProfile.speed must be positive, got {self.speed}"
            )


@dataclasses.dataclass(frozen=True)
class NodeGroup:
    """A contiguous block of identical nodes inside a heterogeneous platform.

    ``speed`` is the group's operating compute speed (realized wall time of a
    job = nominal runtime / min speed over its allocated nodes — see
    core/SEMANTICS.md §Heterogeneity).
    """

    count: int
    name: str = "default"
    power_active: float = 190.0
    power_idle: float = 190.0
    power_sleep: float = 9.0
    power_switch_on: float = 190.0
    power_switch_off: float = 9.0
    t_switch_on: int = 30 * 60
    t_switch_off: int = 45 * 60
    speed: float = 1.0

    def __post_init__(self):
        if self.count <= 0:
            raise ValueError(f"NodeGroup.count must be positive, got {self.count}")
        if self.speed <= 0:
            raise ValueError(f"NodeGroup.speed must be positive, got {self.speed}")

    def power_table(self) -> Tuple[float, ...]:
        return (
            self.power_sleep,
            self.power_switch_on,
            self.power_idle,
            self.power_active,
            self.power_switch_off,
        )

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "count": self.count,
            "compute_speed": self.speed,
            "states": {
                "sleep": {"power": self.power_sleep},
                "idle": {"power": self.power_idle},
                "active": {"power": self.power_active},
                "switching_on": {
                    "power": self.power_switch_on,
                    "transition_time": self.t_switch_on,
                },
                "switching_off": {
                    "power": self.power_switch_off,
                    "transition_time": self.t_switch_off,
                },
            },
        }


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """Hardware description of the simulated machine.

    Attributes mirror the paper's platform JSON. The scalar fields describe a
    homogeneous machine; ``node_groups`` (when non-empty) describes a
    heterogeneous one and takes precedence — the scalar fields then only act
    as defaults mirrored from the first group. Node ids run contiguously in
    group order.
    """

    nb_nodes: int
    power_active: float = 190.0
    power_idle: float = 190.0
    power_sleep: float = 9.0
    power_switch_on: float = 190.0
    power_switch_off: float = 9.0
    t_switch_on: int = 30 * 60  # seconds (paper: 30 minutes)
    t_switch_off: int = 45 * 60  # seconds (paper: 45 minutes)
    compute_speed: float = 1.0
    dvfs_profiles: tuple = ()
    dvfs_mode: Optional[str] = None
    node_groups: Tuple[NodeGroup, ...] = ()

    def __post_init__(self):
        if self.compute_speed <= 0:
            raise ValueError(
                f"compute_speed must be positive, got {self.compute_speed}"
            )
        if self.node_groups:
            object.__setattr__(self, "node_groups", tuple(self.node_groups))
            total = sum(g.count for g in self.node_groups)
            if total != self.nb_nodes:
                raise ValueError(
                    f"node_groups cover {total} nodes != nb_nodes {self.nb_nodes}"
                )
            # keep the legacy scalar views mirrored from the first group on
            # every construction path (not just platform_from_groups), so
            # legacy callers never see defaults that disagree with the
            # per-node tables the engines actually simulate
            g0 = self.node_groups[0]
            object.__setattr__(self, "power_active", g0.power_active)
            object.__setattr__(self, "power_idle", g0.power_idle)
            object.__setattr__(self, "power_sleep", g0.power_sleep)
            object.__setattr__(self, "power_switch_on", g0.power_switch_on)
            object.__setattr__(self, "power_switch_off", g0.power_switch_off)
            object.__setattr__(self, "t_switch_on", g0.t_switch_on)
            object.__setattr__(self, "t_switch_off", g0.t_switch_off)
            object.__setattr__(self, "compute_speed", g0.speed)

    # ---- group views -----------------------------------------------------
    @property
    def is_heterogeneous(self) -> bool:
        return len(self.node_groups) > 1

    def groups(self) -> Tuple[NodeGroup, ...]:
        """Group view; a homogeneous spec synthesizes one group from scalars."""
        if self.node_groups:
            return self.node_groups
        return (
            NodeGroup(
                count=self.nb_nodes,
                power_active=self.power_active,
                power_idle=self.power_idle,
                power_sleep=self.power_sleep,
                power_switch_on=self.power_switch_on,
                power_switch_off=self.power_switch_off,
                t_switch_on=self.t_switch_on,
                t_switch_off=self.t_switch_off,
                speed=self.speed(),
            ),
        )

    def n_groups(self) -> int:
        return len(self.groups())

    def group_names(self) -> Tuple[str, ...]:
        return tuple(g.name for g in self.groups())

    # ---- per-node tables (the engines' native representation) ------------
    def node_group_id(self) -> np.ndarray:
        """i32[N] group index of every node (contiguous in group order)."""
        return np.repeat(
            np.arange(len(self.groups()), dtype=np.int32),
            [g.count for g in self.groups()],
        )

    def node_power_table(self) -> np.ndarray:
        """f32[N, 5] per-node per-state watts."""
        return np.repeat(
            np.asarray([g.power_table() for g in self.groups()], np.float32),
            [g.count for g in self.groups()],
            axis=0,
        )

    def node_t_switch_on(self) -> np.ndarray:
        """i32[N] per-node switch-on delay (s)."""
        return np.repeat(
            np.asarray([g.t_switch_on for g in self.groups()], np.int32),
            [g.count for g in self.groups()],
        )

    def node_t_switch_off(self) -> np.ndarray:
        """i32[N] per-node switch-off delay (s)."""
        return np.repeat(
            np.asarray([g.t_switch_off for g in self.groups()], np.int32),
            [g.count for g in self.groups()],
        )

    def node_speed(self) -> np.ndarray:
        """f32[N] per-node compute speed (realized runtime = work / speed)."""
        return np.repeat(
            np.asarray([g.speed for g in self.groups()], np.float32),
            [g.count for g in self.groups()],
        )

    def node_order_key(self) -> np.ndarray:
        """f32[N] allocation preference key: active watts per unit of work.

        Lower is better ("cheap/fast first"); computed in float32 so the JAX
        engine and the Python oracle order nodes identically
        (core/SEMANTICS.md §Heterogeneity).
        """
        table = self.node_power_table()
        return (table[:, ACTIVE] / self.node_speed()).astype(np.float32)

    def group_active_powers(self) -> Tuple[float, ...]:
        return tuple(g.power_active for g in self.groups())

    # ---- legacy scalar views ---------------------------------------------
    def power_table(self):
        """Per-state power draw indexed by the state encoding above."""
        return (
            self.power_sleep,
            self.power_switch_on,
            self.power_idle,
            self.power_active,
            self.power_switch_off,
        )

    def speed(self) -> float:
        if self.dvfs_mode:
            for p in self.dvfs_profiles:
                if p.name == self.dvfs_mode:
                    return p.speed
        return self.compute_speed

    def to_json(self) -> Dict[str, Any]:
        out = {
            "nb_nodes": self.nb_nodes,
            "compute_speed": self.compute_speed,
            "dvfs_mode": self.dvfs_mode,
            "dvfs_profiles": [dataclasses.asdict(p) for p in self.dvfs_profiles],
            "states": {
                "sleep": {"power": self.power_sleep},
                "idle": {"power": self.power_idle},
                "active": {"power": self.power_active},
                "switching_on": {
                    "power": self.power_switch_on,
                    "transition_time": self.t_switch_on,
                },
                "switching_off": {
                    "power": self.power_switch_off,
                    "transition_time": self.t_switch_off,
                },
            },
            "transitions": [
                {"from": "sleep", "to": "switching_on"},
                {"from": "switching_on", "to": "idle"},
                {"from": "idle", "to": "active"},
                {"from": "active", "to": "idle"},
                {"from": "idle", "to": "switching_off"},
                {"from": "switching_off", "to": "sleep"},
            ],
        }
        if self.node_groups:
            out["node_groups"] = [g.to_json() for g in self.node_groups]
        return out

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)


def make_platform(nb_nodes: int, **kw) -> PlatformSpec:
    return PlatformSpec(nb_nodes=nb_nodes, **kw)


def platform_from_groups(groups: Sequence[NodeGroup], **kw) -> PlatformSpec:
    """Build a PlatformSpec from node groups (nb_nodes derived).

    A single group collapses to the equivalent scalar (homogeneous) spec so
    that "N identical nodes" and "one machine of N nodes" are literally the
    same object — the metamorphic guarantee tests rely on.
    """
    groups = tuple(groups)
    if not groups:
        raise ValueError("platform_from_groups needs at least one group")
    if len(groups) == 1:
        g = groups[0]
        return PlatformSpec(
            nb_nodes=g.count,
            power_active=g.power_active,
            power_idle=g.power_idle,
            power_sleep=g.power_sleep,
            power_switch_on=g.power_switch_on,
            power_switch_off=g.power_switch_off,
            t_switch_on=g.t_switch_on,
            t_switch_off=g.t_switch_off,
            compute_speed=g.speed,
            **kw,
        )
    # __post_init__ mirrors the scalar views from the first group
    return PlatformSpec(
        nb_nodes=sum(g.count for g in groups),
        node_groups=groups,
        **kw,
    )


def _states_from_json(
    states: Mapping[str, Any], defaults: Mapping[str, float]
) -> Dict[str, Any]:
    def p(name, default):
        return float(states.get(name, {}).get("power", default))

    def t(name, default):
        return int(states.get(name, {}).get("transition_time", default))

    active = p("active", defaults["power_active"])
    return {
        "power_active": active,
        # idle inherits the document-level idle like every other state; only
        # when the document doesn't set one does it default to this entry's
        # active draw (the paper's idle==active illustrative setup)
        "power_idle": p("idle", defaults.get("power_idle", active)),
        "power_sleep": p("sleep", defaults["power_sleep"]),
        "power_switch_on": p("switching_on", defaults["power_switch_on"]),
        "power_switch_off": p("switching_off", defaults["power_switch_off"]),
        "t_switch_on": t("switching_on", defaults["t_switch_on"]),
        "t_switch_off": t("switching_off", defaults["t_switch_off"]),
    }


_DEFAULTS = {
    "power_active": 190.0,
    "power_sleep": 9.0,
    "power_switch_on": 190.0,
    "power_switch_off": 9.0,
    "t_switch_on": 1800,
    "t_switch_off": 2700,
}


def _group_from_json(
    d: Mapping[str, Any],
    defaults: Mapping[str, float],
    index: int,
    count: int,
    default_speed: float = 1.0,
) -> NodeGroup:
    fields = _states_from_json(d.get("states", {}), defaults)
    return NodeGroup(
        count=count,
        name=str(d.get("name", f"group{index}")),
        speed=float(d.get("compute_speed", d.get("speed", default_speed))),
        **fields,
    )


def _coalesce_nodes(entries: List[NodeGroup]) -> Tuple[NodeGroup, ...]:
    """Merge consecutive per-node entries that are identical up to the name.

    The JSON loader *preserves* per-node heterogeneity, but N identical
    entries collapse into one group of N so a homogeneous platform written
    node-by-node is indistinguishable from its scalar form (metamorphic
    guarantee; also keeps the engine's group axis small).
    """
    out: List[NodeGroup] = []
    for g in entries:
        if out and dataclasses.replace(
            out[-1], count=g.count, name=g.name
        ) == g:
            out[-1] = dataclasses.replace(
                out[-1], count=out[-1].count + g.count
            )
        else:
            out.append(g)
    return tuple(out)


def _from_json(obj: Mapping[str, Any]) -> PlatformSpec:
    profiles = tuple(
        DvfsProfile(d["name"], float(d["power"]), float(d.get("speed", 1.0)))
        for d in obj.get("dvfs_profiles", [])
    )
    top = _states_from_json(obj.get("states", {}), _DEFAULTS)
    common = dict(
        dvfs_profiles=profiles,
        dvfs_mode=obj.get("dvfs_mode"),
    )

    # document-level compute_speed is the default for every group/node entry,
    # matching the homogeneous loader's semantics
    default_speed = float(obj.get("compute_speed", 1.0))
    group_defaults = {**_DEFAULTS, **top}
    if "idle" not in obj.get("states", {}):
        # only an *explicit* document idle inherits into groups; otherwise an
        # entry's idle defaults to its own active draw (paper idle==active)
        group_defaults.pop("power_idle", None)
    groups: List[NodeGroup] = []
    if "node_groups" in obj:
        for i, d in enumerate(obj["node_groups"]):
            groups.append(
                _group_from_json(
                    d, group_defaults, i, int(d["count"]), default_speed
                )
            )
    elif "nodes" in obj:
        # per-node entries (the paper schema's per-node form) are preserved,
        # with identical neighbours coalesced into groups
        for i, d in enumerate(obj["nodes"]):
            groups.append(
                _group_from_json(
                    d, group_defaults, i, int(d.get("count", 1)),
                    default_speed,
                )
            )
        groups = list(_coalesce_nodes(groups))

    if groups:
        spec = platform_from_groups(tuple(groups), **common)
        if "nb_nodes" in obj and int(obj["nb_nodes"]) != spec.nb_nodes:
            raise ValueError(
                f"nb_nodes {obj['nb_nodes']} != nodes described {spec.nb_nodes}"
            )
        return spec

    return PlatformSpec(
        nb_nodes=int(obj["nb_nodes"]),
        compute_speed=float(obj.get("compute_speed", 1.0)),
        **top,
        **common,
    )


def load_platform(path_or_obj) -> PlatformSpec:
    """Load a platform from a JSON file path or a parsed dict.

    Accepts three schema forms: flat scalar ``states`` (homogeneous),
    ``node_groups`` (list of {name, count, states, compute_speed}), and
    ``nodes`` (one entry per node; identical neighbours are coalesced but
    distinct per-node entries are preserved — never silently collapsed).
    """
    if isinstance(path_or_obj, Mapping):
        return _from_json(path_or_obj)
    with open(path_or_obj) as f:
        return _from_json(json.load(f))


def mixed_platform_example(nb_nodes: int = 16) -> PlatformSpec:
    """Canonical 3-group heterogeneous example used by tests and benchmarks.

    fast: hot 2x-speed nodes · eco: cool 0.5x nodes · std: paper Table 3.
    Different idle/sleep watts, asymmetric transition delays, 2x/0.5x/1x
    speeds — one third of the machine each (remainder to std).
    """
    a = nb_nodes // 3
    b = nb_nodes // 3
    return platform_from_groups(
        (
            NodeGroup(count=a, name="fast", power_active=300.0,
                      power_idle=250.0, power_sleep=12.0,
                      power_switch_on=300.0, power_switch_off=12.0,
                      t_switch_on=600, t_switch_off=900, speed=2.0),
            NodeGroup(count=b, name="eco", power_active=100.0,
                      power_idle=80.0, power_sleep=4.0,
                      power_switch_on=100.0, power_switch_off=4.0,
                      t_switch_on=120, t_switch_off=180, speed=0.5),
            NodeGroup(count=nb_nodes - a - b, name="std"),
        )
    )


# Paper Table 3 (power model); node count chosen per workload trace.
DEFAULT_PLATFORM = PlatformSpec(nb_nodes=128)
