"""Workload model — the paper's ``workload.json`` (§2.3.1, Table 2) + SWF parser.

A workload is a job stream: (job_id, res, subtime, reqtime, runtime, user_id,
profile). ``parse_swf`` reads the Parallel Workloads Archive Standard Workload
Format so real traces (NASA iPSC/860, CIEMAT Euler, CEA Curie) drop in when
available; the container is offline so tests/benchmarks use the seeded
generator presets in :mod:`repro.workloads.generator`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Job:
    job_id: int
    res: int  # requested nodes
    subtime: int  # submission time (s)
    reqtime: int  # requested wall-time (s)
    runtime: int  # realized runtime (s)
    user_id: int = 0
    profile: str = "default"


@dataclasses.dataclass(frozen=True)
class Workload:
    nb_res: int  # max nodes a job may request (paper Table 2)
    jobs: tuple  # tuple[Job]

    def __post_init__(self):
        object.__setattr__(self, "jobs", tuple(self.jobs))

    def __len__(self) -> int:
        return len(self.jobs)

    def sorted_by_subtime(self) -> "Workload":
        return Workload(
            self.nb_res,
            tuple(sorted(self.jobs, key=lambda j: (j.subtime, j.job_id))),
        )

    def tail(self, n: int) -> "Workload":
        """Last ``n`` jobs by submission order (paper uses trace tails)."""
        jobs = sorted(self.jobs, key=lambda j: (j.subtime, j.job_id))[-n:]
        if not jobs:
            return Workload(self.nb_res, ())
        t0 = jobs[0].subtime
        shifted = tuple(
            dataclasses.replace(j, subtime=j.subtime - t0) for j in jobs
        )
        return Workload(self.nb_res, shifted)

    # ---- array views for the JAX engine ----
    def arrays(self) -> Dict[str, np.ndarray]:
        j = self.sorted_by_subtime().jobs
        return {
            "job_id": np.array([x.job_id for x in j], np.int32),
            "res": np.array([x.res for x in j], np.int32),
            "subtime": np.array([x.subtime for x in j], np.int32),
            "reqtime": np.array([x.reqtime for x in j], np.int32),
            "runtime": np.array([x.runtime for x in j], np.int32),
        }

    def to_json(self) -> Dict[str, Any]:
        return {
            "nb_res": self.nb_res,
            "jobs": [
                {
                    "job_id": j.job_id,
                    "res": j.res,
                    "subtime": j.subtime,
                    "user_id": j.user_id,
                    "reqtime": j.reqtime,
                    "runtime": j.runtime,
                    "profile": j.profile,
                }
                for j in self.jobs
            ],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)


def _job_from_json(d: Mapping[str, Any]) -> Job:
    return Job(
        job_id=int(d["job_id"]),
        res=int(d["res"]),
        subtime=int(d["subtime"]),
        reqtime=int(d.get("reqtime", d.get("walltime", d["runtime"]))),
        runtime=int(d["runtime"]),
        user_id=int(d.get("user_id", 0)),
        profile=str(d.get("profile", "default")),
    )


def load_workload(path_or_obj) -> Workload:
    """Load a workload from a JSON file path or a parsed dict."""
    if isinstance(path_or_obj, Mapping):
        obj = path_or_obj
    else:
        with open(path_or_obj) as f:
            obj = json.load(f)
    jobs = tuple(_job_from_json(d) for d in obj["jobs"])
    nb_res = int(obj.get("nb_res", max((j.res for j in jobs), default=1)))
    return Workload(nb_res=nb_res, jobs=jobs).sorted_by_subtime()


def swf_header_maxprocs(line: str) -> Optional[int]:
    """MaxProcs value of an SWF header comment line, if it carries one."""
    if line.startswith(";") and "MaxProcs" in line:
        try:
            return int(line.split(":")[-1])
        except ValueError:
            return None
    return None


def swf_line_job(line: str) -> Optional[Job]:
    """Parse one SWF data line into a :class:`Job`, or None if the line is
    blank, a comment, ragged, or a dropped record.

    SWF fields used: 1 job id, 2 submit time, 4 run time, 5 allocated procs,
    8 requested procs, 9 requested time. Jobs with unknown (-1) runtime or
    zero resources are dropped, matching common SWF-cleaning practice.
    This is the ONE cleaning rule — :func:`parse_swf` and the streaming
    reader in :mod:`repro.workloads.traces` both go through it, so the two
    readers can never drift.
    """
    line = line.strip()
    if not line or line.startswith(";"):
        return None
    parts = line.split()
    if len(parts) < 9:
        return None
    jid = int(parts[0])
    subtime = int(float(parts[1]))
    runtime = int(float(parts[3]))
    alloc = int(parts[4])
    req_procs = int(parts[7])
    reqtime = int(float(parts[8]))
    res = req_procs if req_procs > 0 else alloc
    if runtime < 0 or res <= 0:
        return None
    if reqtime <= 0:
        reqtime = max(runtime, 1)
    return Job(
        job_id=jid,
        res=res,
        subtime=subtime,
        reqtime=max(reqtime, runtime, 1),
        runtime=max(runtime, 1),
    )


def parse_swf(path: str, max_jobs: Optional[int] = None) -> Workload:
    """Parse a Standard Workload Format trace (Parallel Workloads Archive).

    Cleaning rules live in :func:`swf_line_job`. For Curie-scale traces the
    chunked streaming reader :func:`repro.workloads.traces.read_swf` parses
    the same format without holding every raw line.
    """
    jobs: List[Job] = []
    nb_res = 0
    with open(path) as f:
        for line in f:
            mp = swf_header_maxprocs(line.strip())
            if mp is not None:
                nb_res = mp
                continue
            job = swf_line_job(line)
            if job is None:
                continue
            jobs.append(job)
            if max_jobs is not None and len(jobs) >= max_jobs:
                break
    if nb_res == 0:
        nb_res = max((j.res for j in jobs), default=1)
    return Workload(nb_res=nb_res, jobs=tuple(jobs)).sorted_by_subtime()


def workload_from_arrays(
    res: Sequence[int],
    subtime: Sequence[int],
    runtime: Sequence[int],
    reqtime: Optional[Sequence[int]] = None,
    nb_res: Optional[int] = None,
) -> Workload:
    n = len(res)
    reqtime = reqtime if reqtime is not None else runtime
    jobs = tuple(
        Job(
            job_id=i,
            res=int(res[i]),
            subtime=int(subtime[i]),
            reqtime=int(reqtime[i]),
            runtime=int(runtime[i]),
        )
        for i in range(n)
    )
    return Workload(
        nb_res=int(nb_res if nb_res is not None else max((j.res for j in jobs), default=1)),
        jobs=jobs,
    ).sorted_by_subtime()
