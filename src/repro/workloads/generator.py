"""Seeded synthetic workload generator (paper §2.3.1: "SPARS includes a
workload generator ... arrival rate, average execution time and variability,
min/max nodes per job, number of jobs").

Presets approximate the published summary statistics of the three traces used
in the paper's illustrative examples (the container is offline, so the real
Parallel Workloads Archive files cannot be fetched; ``parse_swf`` accepts them
when present):

* ``nasa_ipsc``    — NASA Ames iPSC/860: 128 nodes, power-of-two requests.
* ``ciemat_euler`` — CIEMAT Euler: 64 nodes.
* ``cea_curie``    — CEA Curie: 11 200 nodes (large-scale benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.workloads.workload import Job, Workload


@dataclasses.dataclass(frozen=True)
class GeneratorConfig:
    n_jobs: int = 200
    nb_res: int = 16
    # inter-arrival: exponential with this mean (seconds)
    mean_interarrival: float = 120.0
    # runtime: lognormal, parameterized by mean and coefficient of variation
    mean_runtime: float = 1800.0
    cv_runtime: float = 1.5
    min_res: int = 1
    max_res: Optional[int] = None  # default nb_res
    power_of_two: bool = False  # request sizes drawn from powers of two
    # requested walltime = runtime * U[1, overreq_factor] (terminate-overrun
    # scenarios instead use reqtime < runtime with prob overrun_prob)
    overreq_factor: float = 3.0
    overrun_prob: float = 0.0
    seed: int = 0


def generate_workload(config: GeneratorConfig = GeneratorConfig(), **kw) -> Workload:
    """Generate a reproducible synthetic workload."""
    if kw:
        config = dataclasses.replace(config, **kw)
    rng = np.random.default_rng(config.seed)
    n = config.n_jobs
    max_res = config.max_res or config.nb_res

    inter = rng.exponential(config.mean_interarrival, size=n)
    subtime = np.floor(np.cumsum(inter)).astype(np.int64)
    subtime[0] = 0

    # lognormal with target mean/cv
    cv2 = config.cv_runtime**2
    sigma2 = np.log1p(cv2)
    mu = np.log(config.mean_runtime) - sigma2 / 2.0
    runtime = np.maximum(
        1, np.round(rng.lognormal(mu, np.sqrt(sigma2), size=n))
    ).astype(np.int64)

    if config.power_of_two:
        max_pow = int(np.log2(max_res))
        min_pow = int(np.ceil(np.log2(max(config.min_res, 1))))
        # favor small jobs (heavy-tailed size distribution, as in NASA trace)
        pows = np.arange(min_pow, max_pow + 1)
        w = 1.0 / (pows - min_pow + 1.0)
        res = 2 ** rng.choice(pows, size=n, p=w / w.sum())
    else:
        lo, hi = config.min_res, max_res
        # discretized truncated geometric-ish: small jobs dominate
        u = rng.uniform(size=n)
        res = np.clip(
            np.round(lo + (hi - lo) * (u**2)), lo, hi
        ).astype(np.int64)

    over = rng.uniform(1.0, config.overreq_factor, size=n)
    reqtime = np.maximum(1, np.round(runtime * over)).astype(np.int64)
    if config.overrun_prob > 0:
        # some users underestimate: requested < actual -> overrun (terminated
        # under the terminate-overrun policy)
        mask = rng.uniform(size=n) < config.overrun_prob
        reqtime[mask] = np.maximum(1, (runtime[mask] * 0.6).astype(np.int64))

    jobs = tuple(
        Job(
            job_id=i,
            res=int(res[i]),
            subtime=int(subtime[i]),
            reqtime=int(reqtime[i]),
            runtime=int(runtime[i]),
            user_id=int(rng.integers(0, 16)),
        )
        for i in range(n)
    )
    return Workload(nb_res=config.nb_res, jobs=jobs).sorted_by_subtime()


PRESETS = {
    # paper Table 3: 128 nodes, last 10 839 jobs (scaled-down default here;
    # benchmarks override n_jobs where the full count matters)
    "nasa_ipsc": GeneratorConfig(
        n_jobs=2000,
        nb_res=128,
        mean_interarrival=540.0,
        mean_runtime=1200.0,
        cv_runtime=2.2,
        power_of_two=True,
        overreq_factor=4.0,
        seed=1860,
    ),
    "ciemat_euler": GeneratorConfig(
        n_jobs=1000,
        nb_res=64,
        mean_interarrival=900.0,
        mean_runtime=3600.0,
        cv_runtime=2.8,
        power_of_two=False,
        overreq_factor=5.0,
        seed=2017,
    ),
    "cea_curie": GeneratorConfig(
        n_jobs=1000,
        nb_res=11200,
        mean_interarrival=300.0,
        mean_runtime=5400.0,
        cv_runtime=3.0,
        min_res=1,
        max_res=8192,
        power_of_two=False,
        overreq_factor=6.0,
        seed=1300,
    ),
    # paper Fig. 3: 200 random jobs on 16 nodes
    "fig3_small": GeneratorConfig(
        n_jobs=200,
        nb_res=16,
        mean_interarrival=60.0,
        mean_runtime=300.0,
        cv_runtime=1.2,
        overreq_factor=2.0,
        overrun_prob=0.15,
        seed=3,
    ),
}


def preset(name: str, **kw) -> Workload:
    cfg = PRESETS[name]
    return generate_workload(cfg, **kw)
