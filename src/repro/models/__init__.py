"""Model substrate: the assigned architectures as composable JAX modules.

``build_model(arch_config)`` returns pure ``init / loss / prefill /
decode_step`` functions; parameters are plain pytrees (stacked per layer-stage
for ``lax.scan``), sharding rules live in :mod:`repro.models.sharding`.
"""
from repro.models.model import Model, build_model

__all__ = ["Model", "build_model"]
