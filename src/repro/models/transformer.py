"""Generic block-programmed LM stack.

An architecture is a *block program*: a tuple of (block_type, count) stages
(``ArchConfig.block_program()``). Within a stage, per-layer parameters are
stacked on a leading axis and applied with ``lax.scan`` (+ optional
``jax.checkpoint`` remat), keeping the HLO size O(#stage-types) rather than
O(#layers) — essential for compiling 314B-parameter programs quickly.

Block types:
  dense        attn(GQA/RoPE/qk-norm) + SwiGLU
  moe          attn + (shared + routed top-k) experts
  zamba_super  ``mamba_per_super`` Mamba-2 blocks + one weight-tied shared
               attention block (Zamba2 hybrid pattern)
  xlstm_pair   mLSTM block + sLSTM block (xLSTM alternation)
  enc          bidirectional attn + GELU MLP (whisper encoder)
  dec          causal self-attn + cross-attn + GELU MLP (whisper decoder)

Three execution paths per model: ``loss``/``forward`` (training, no cache),
``prefill`` (build caches, return last-position logits), ``decode_step``
(one token, O(1) or O(ctx) per step). Caches are plain pytrees whose
structure mirrors the stage list.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

PyTree = Any


def _tp_out(h: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Mark a post-TP-all-reduce activation for the save_tp remat policy."""
    if cfg.remat and cfg.remat_policy == "save_tp":
        from jax.ad_checkpoint import checkpoint_name

        return checkpoint_name(h, "tp_out")
    return h


def _remat(fn, cfg: ArchConfig):
    """Wrap a scan body with the configured rematerialization policy."""
    if cfg.remat_policy == "save_tp":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names("tp_out")
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------

def _attn_block_init(key, cfg: ArchConfig, d_ff: int, gelu: bool, causal_dec=False):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": L.attn_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qk_norm, cfg.dtype
        ),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        "mlp": (
            L.gelu_mlp_init(k2, cfg.d_model, d_ff, cfg.dtype)
            if gelu
            else L.swiglu_init(k2, cfg.d_model, d_ff, cfg.dtype)
        ),
    }
    return p


def block_init(key, cfg: ArchConfig, block_type: str) -> PyTree:
    if block_type == "dense":
        return _attn_block_init(key, cfg, cfg.d_ff, gelu=False)
    if block_type == "enc":
        return _attn_block_init(key, cfg, cfg.d_ff, gelu=True)
    if block_type == "dec":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "self_attn": L.attn_init(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qk_norm, cfg.dtype
            ),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
            "cross_attn": L.attn_init(
                k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, False, cfg.dtype
            ),
            "ln3": jnp.ones((cfg.d_model,), cfg.dtype),
            "mlp": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.dtype),
        }
    if block_type == "moe":
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "attn": L.attn_init(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qk_norm, cfg.dtype
            ),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
            "moe": M.moe_init(
                k2,
                cfg.d_model,
                cfg.n_experts,
                cfg.expert_d_ff,
                cfg.n_shared_experts,
                cfg.shared_d_ff,
                cfg.dtype,
            ),
        }
    if block_type == "mamba2":
        dims = _mamba_dims(cfg)
        return {
            "ln": jnp.ones((cfg.d_model,), cfg.dtype),
            "mixer": S.mamba2_init(key, dims, cfg.dtype),
        }
    if block_type == "zamba_super":
        ks = jax.random.split(key, cfg.mamba_per_super)
        return {
            "mamba": jax.vmap(
                lambda k: block_init(k, cfg, "mamba2")
            )(jnp.stack(ks)),
        }
    if block_type == "mlstm":
        dims = S.MLstmDims.make(cfg.d_model, cfg.n_heads, cfg.ssm_expand)
        return {
            "ln": jnp.ones((cfg.d_model,), cfg.dtype),
            "mixer": S.mlstm_init(key, dims, cfg.dtype),
        }
    if block_type == "slstm":
        dims = S.SLstmDims.make(cfg.d_model, cfg.n_heads)
        return {
            "ln": jnp.ones((cfg.d_model,), cfg.dtype),
            "mixer": S.slstm_init(key, dims, cfg.dtype),
        }
    if block_type == "xlstm_pair":
        k1, k2 = jax.random.split(key)
        return {
            "mlstm": block_init(k1, cfg, "mlstm"),
            "slstm": block_init(k2, cfg, "slstm"),
        }
    raise ValueError(f"unknown block type {block_type}")


def _mamba_dims(cfg: ArchConfig) -> S.Mamba2Dims:
    return S.Mamba2Dims.make(
        cfg.d_model, cfg.ssm_state, cfg.ssm_expand, cfg.ssm_head_dim
    )


# ---------------------------------------------------------------------------
# per-block apply (train path: no cache)
# ---------------------------------------------------------------------------

def _attn_args(cfg: ArchConfig, rope: bool):
    return dict(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        theta=cfg.rope_theta if rope else 0.0,
        qk_norm=cfg.qk_norm,
        eps=cfg.norm_eps,
        chunk=cfg.attn_chunk,
    )


def _shared_attn_apply(shared, x, cfg, positions, cache=None, cache_pos=None):
    h, kv = L.attn_apply(
        shared["attn"],
        L.rms_norm(x, shared["ln1"], cfg.norm_eps),
        positions=positions,
        causal=True,
        cache=cache,
        cache_pos=cache_pos,
        **_attn_args(cfg, rope=True),
    )
    x = x + h
    x = x + L.swiglu_apply(shared["mlp"], L.rms_norm(x, shared["ln2"], cfg.norm_eps))
    return x, kv


def block_apply(
    p: PyTree,
    x: jax.Array,
    cfg: ArchConfig,
    block_type: str,
    positions: jax.Array,
    extras: Dict[str, Any],
    cache: Optional[PyTree] = None,
    cache_pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """Apply one block. Returns (x, new_cache, aux_loss)."""
    eps = cfg.norm_eps
    zero = jnp.zeros((), jnp.float32)

    if block_type in ("dense", "moe"):
        h, kv = L.attn_apply(
            p["attn"],
            L.rms_norm(x, p["ln1"], eps),
            positions=positions,
            causal=True,
            cache=cache,
            cache_pos=cache_pos,
            **_attn_args(cfg, rope=True),
        )
        h = _tp_out(h, cfg)  # post-all-reduce point (remat_policy="save_tp")
        x = x + h
        inner = L.rms_norm(x, p["ln2"], eps)
        if block_type == "dense":
            x = x + _tp_out(L.swiglu_apply(p["mlp"], inner), cfg)
            return x, kv, zero
        y, aux = M.moe_apply(
            p["moe"],
            inner,
            n_experts=cfg.n_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            batch_axes=cfg.batch_axes,
        )
        return x + _tp_out(y, cfg), kv, aux

    if block_type == "enc":
        h, _ = L.attn_apply(
            p["attn"],
            L.rms_norm(x, p["ln1"], eps),
            positions=positions,
            causal=False,
            **_attn_args(cfg, rope=False),
        )
        x = x + h
        x = x + L.gelu_mlp_apply(p["mlp"], L.rms_norm(x, p["ln2"], eps))
        return x, None, zero

    if block_type == "dec":
        h, self_kv = L.attn_apply(
            p["self_attn"],
            L.rms_norm(x, p["ln1"], eps),
            positions=positions,
            causal=True,
            cache=None if cache is None else cache["self"],
            cache_pos=cache_pos,
            **_attn_args(cfg, rope=True),
        )
        x = x + h
        # cross attention over encoder memory
        xq = L.rms_norm(x, p["ln2"], eps)
        b, s, _ = xq.shape
        q = (xq @ p["cross_attn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
        if cache is not None and "cross" in cache and extras.get("memory") is None:
            km, vm = cache["cross"]
        else:
            mem = extras["memory"]
            km = (mem @ p["cross_attn"]["wk"]).reshape(
                mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.hd
            )
            vm = (mem @ p["cross_attn"]["wv"]).reshape(
                mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.hd
            )
        attn_out = L.attention_naive(q, km, vm, causal=False)
        x = x + attn_out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["cross_attn"]["wo"]
        x = x + L.gelu_mlp_apply(p["mlp"], L.rms_norm(x, p["ln3"], eps))
        new_cache = None if cache is None else {"self": self_kv, "cross": (km, vm)}
        return x, new_cache, zero

    if block_type == "mamba2":
        dims = _mamba_dims(cfg)
        inner = L.rms_norm(x, p["ln"], eps)
        if cache is None:
            y, _ = S.mamba2_apply(p["mixer"], inner, dims, chunk=cfg.gla_chunk, eps=eps)
            return x + y, None, zero
        if x.shape[1] == 1:  # decode
            y, st = S.mamba2_decode(p["mixer"], inner[:, 0], dims, cache, eps=eps)
            return x + y[:, None], st, zero
        y, st = S.mamba2_apply(
            p["mixer"], inner, dims, h0=cache[0], conv0=cache[1],
            chunk=cfg.gla_chunk, eps=eps,
        )
        return x + y, st, zero

    if block_type == "mlstm":
        dims = S.MLstmDims.make(cfg.d_model, cfg.n_heads, cfg.ssm_expand)
        inner = L.rms_norm(x, p["ln"], eps)
        if cache is None:
            y, _ = S.mlstm_apply(p["mixer"], inner, dims, chunk=cfg.gla_chunk, eps=eps)
            return x + y, None, zero
        if x.shape[1] == 1:
            y, st = S.mlstm_decode(p["mixer"], inner[:, 0], dims, cache, eps=eps)
            return x + y[:, None], st, zero
        y, st = S.mlstm_apply(
            p["mixer"], inner, dims, state=cache, chunk=cfg.gla_chunk, eps=eps
        )
        return x + y, st, zero

    if block_type == "slstm":
        dims = S.SLstmDims.make(cfg.d_model, cfg.n_heads)
        inner = L.rms_norm(x, p["ln"], eps)
        if cache is None:
            y, _ = S.slstm_apply(p["mixer"], inner, dims, eps=eps)
            return x + y, None, zero
        if x.shape[1] == 1:
            y, st = S.slstm_decode(p["mixer"], inner[:, 0], dims, cache, eps=eps)
            return x + y[:, None], st, zero
        y, st = S.slstm_apply(p["mixer"], inner, dims, state=cache, eps=eps)
        return x + y, st, zero

    if block_type == "xlstm_pair":
        x, c1, _ = block_apply(
            p["mlstm"], x, cfg, "mlstm", positions, extras,
            None if cache is None else cache["mlstm"], cache_pos,
        )
        x, c2, _ = block_apply(
            p["slstm"], x, cfg, "slstm", positions, extras,
            None if cache is None else cache["slstm"], cache_pos,
        )
        new_cache = None if cache is None else {"mlstm": c1, "slstm": c2}
        return x, new_cache, zero

    if block_type == "zamba_super":
        mamba_cache = None if cache is None else cache["mamba"]

        def mamba_body(carry, xs):
            xx = carry
            if cache is None:
                lp = xs
                xx, _, _ = block_apply(lp, xx, cfg, "mamba2", positions, extras)
                return xx, None
            lp, lc = xs
            xx, nc, _ = block_apply(
                lp, xx, cfg, "mamba2", positions, extras, lc, cache_pos
            )
            return xx, nc

        if cfg.remat and cache is None:
            mamba_body = _remat(mamba_body, cfg)
        xs = p["mamba"] if cache is None else (p["mamba"], mamba_cache)
        x, new_mamba_cache = jax.lax.scan(mamba_body, x, xs)
        # weight-tied shared attention application
        shared = extras["shared"]
        attn_cache = None if cache is None else cache["attn"]
        x, kv = _shared_attn_apply(shared, x, cfg, positions, attn_cache, cache_pos)
        new_cache = (
            None if cache is None else {"mamba": new_mamba_cache, "attn": kv}
        )
        return x, new_cache, zero

    raise ValueError(block_type)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def block_cache(cfg: ArchConfig, block_type: str, batch: int, cache_len: int):
    """Zero cache for ONE layer of the given type."""
    dt = cfg.dtype
    kv = lambda: (
        jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dt),
        jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dt),
    )
    if block_type in ("dense", "moe"):
        return kv()
    if block_type == "dec":
        return {
            "self": kv(),
            "cross": (
                jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dt),
                jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dt),
            ),
        }
    if block_type == "mamba2":
        dims = _mamba_dims(cfg)
        hs, (cxs, cbcs) = S.mamba2_state_shape(dims, batch)
        return (
            jnp.zeros(hs, jnp.float32),
            (jnp.zeros(cxs, jnp.float32), jnp.zeros(cbcs, jnp.float32)),
        )
    if block_type == "zamba_super":
        one = block_cache(cfg, "mamba2", batch, cache_len)
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.mamba_per_super,) + a.shape), one
        )
        return {"mamba": stacked, "attn": kv()}
    if block_type == "mlstm":
        dims = S.MLstmDims.make(cfg.d_model, cfg.n_heads, cfg.ssm_expand)
        hs, ns = S.mlstm_state_shape(dims, batch)
        return (jnp.zeros(hs, jnp.float32), jnp.zeros(ns, jnp.float32))
    if block_type == "slstm":
        dims = S.SLstmDims.make(cfg.d_model, cfg.n_heads)
        return S.slstm_zero_state(dims, batch)
    if block_type == "xlstm_pair":
        return {
            "mlstm": block_cache(cfg, "mlstm", batch, cache_len),
            "slstm": block_cache(cfg, "slstm", batch, cache_len),
        }
    if block_type == "enc":
        return None
    raise ValueError(block_type)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class Model(NamedTuple):
    config: ArchConfig
    init: Callable
    forward: Callable  # (params, batch) -> (logits, aux)
    loss: Callable  # (params, batch) -> (loss, metrics)
    prefill: Callable  # (params, batch) -> (last_logits, cache)
    decode_step: Callable  # (params, tokens[B,1], cache, pos) -> (logits, cache)
    init_cache: Callable  # (batch_size, cache_len) -> cache
    n_params: Callable  # (params) -> int


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / (half - 1)))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def build_model(cfg: ArchConfig) -> Model:
    program = cfg.block_program()
    dt = cfg.dtype
    Vp = cfg.padded_vocab

    # ---------------- init ----------------
    def init(key) -> PyTree:
        n_stage = len(program)
        keys = jax.random.split(key, n_stage + 5)
        params: Dict[str, Any] = {
            "embed": L.embed_init(keys[0], Vp, cfg.d_model, dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": L.dense_init(keys[1], cfg.d_model, Vp, dt),
        }
        stages = []
        for i, (btype, count) in enumerate(program):
            ks = jax.random.split(keys[2 + i], count)
            stages.append(jax.vmap(lambda k: block_init(k, cfg, btype))(jnp.stack(ks)))
        params["stages"] = tuple(stages)
        if any(bt == "zamba_super" for bt, _ in program):
            params["shared_attn"] = {
                "ln1": jnp.ones((cfg.d_model,), dt),
                "attn": L.attn_init(
                    keys[-3], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                    cfg.qk_norm, dt,
                ),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "mlp": L.swiglu_init(keys[-2], cfg.d_model, cfg.d_ff, dt),
            }
        if cfg.encoder_layers:
            ks = jax.random.split(keys[-1], cfg.encoder_layers)
            params["encoder"] = {
                "stage": jax.vmap(lambda k: block_init(k, cfg, "enc"))(jnp.stack(ks)),
                "final_norm": jnp.ones((cfg.d_model,), dt),
            }
        return params

    # ---------------- shared machinery ----------------
    def run_stages(params, x, positions, extras, caches=None, cache_pos=None):
        """caches: tuple parallel to program (stacked per stage) or None."""
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for i, (btype, count) in enumerate(program):
            stage_p = params["stages"][i]
            stage_c = None if caches is None else caches[i]

            if caches is None:

                def body(carry, lp, _btype=btype):
                    xx, aux = carry
                    xx, _, a = block_apply(lp, xx, cfg, _btype, positions, extras)
                    return (xx, aux + a), None

                if cfg.remat:
                    body = _remat(body, cfg)
                (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stage_p)
                new_caches.append(None)
            else:

                def body(carry, xs, _btype=btype):
                    xx, aux = carry
                    lp, lc = xs
                    xx, nc, a = block_apply(
                        lp, xx, cfg, _btype, positions, extras, lc, cache_pos
                    )
                    return (xx, aux + a), nc

                (x, aux_total), nc = jax.lax.scan(
                    body, (x, aux_total), (stage_p, stage_c)
                )
                new_caches.append(nc)
        return x, tuple(new_caches), aux_total

    def encode(params, frames):
        """Whisper encoder over precomputed (stub) frame embeddings."""
        b, s, _ = frames.shape
        pos = jnp.arange(s)
        x = frames.astype(dt) + _sinusoidal(pos, cfg.d_model).astype(dt)[None]
        enc = params["encoder"]

        def body(xx, lp):
            xx, _, _ = block_apply(lp, xx, cfg, "enc", pos, {})
            return xx, None

        if cfg.remat:
            body = _remat(body, cfg)
        x, _ = jax.lax.scan(body, x, enc["stage"])
        return L.rms_norm(x, enc["final_norm"], cfg.norm_eps)

    def embed_inputs(params, batch):
        tokens = batch["tokens"]
        x = params["embed"][jnp.clip(tokens, 0, Vp - 1)]
        if cfg.n_image_embeds:
            img = batch["image_embeds"].astype(dt)  # [B, n_img, D]
            x = jnp.concatenate([img, x[:, cfg.n_image_embeds :]], 1)
        return x

    def make_extras(params, batch, memory="auto"):
        extras: Dict[str, Any] = {}
        if "shared_attn" in params:
            extras["shared"] = params["shared_attn"]
        if cfg.encoder_layers:
            if isinstance(memory, str) and memory == "auto":
                memory = encode(params, batch["encoder_frames"])
            extras["memory"] = memory  # None => read cross-KV from the cache
        return extras

    def lm_logits(params, x):
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return (x @ params["lm_head"]).astype(jnp.float32)

    # ---------------- train ----------------
    def forward(params, batch):
        x = embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        extras = make_extras(params, batch)
        x, _, aux = run_stages(params, x, positions, extras)
        return lm_logits(params, x), aux

    def loss(params, batch):
        logits, aux = forward(params, batch)
        targets = batch["tokens"][:, 1:]
        lg = logits[:, :-1]
        mask = (targets >= 0).astype(jnp.float32)
        if cfg.n_image_embeds:
            pos_mask = jnp.arange(targets.shape[1]) >= cfg.n_image_embeds
            mask = mask * pos_mask[None, :]
        tgt = jnp.clip(targets, 0, Vp - 1)
        logz = jax.nn.logsumexp(lg, -1)
        gold = jnp.take_along_axis(lg, tgt[..., None], -1)[..., 0]
        nll = (logz - gold) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll) / denom
        total = ce + cfg.moe_aux_weight * aux
        return total, {"ce": ce, "aux": aux, "tokens": denom}

    # ---------------- serve ----------------
    def init_cache(batch_size: int, cache_len: int):
        return tuple(
            jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy() if a is not None else None,
                block_cache(cfg, btype, batch_size, cache_len),
                is_leaf=lambda a: a is None,
            )
            if block_cache(cfg, btype, batch_size, cache_len) is not None
            else None
            for btype, count in program
        )

    def prefill(params, batch, cache_len: Optional[int] = None):
        x = embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.arange(s)
        extras = make_extras(params, batch)
        caches = init_cache(b, cache_len or s)
        x, caches, _ = run_stages(
            params, x, positions, extras, caches, jnp.asarray(0, jnp.int32)
        )
        return lm_logits(params, x[:, -1:]), caches

    def decode_step(params, tokens, caches, pos):
        """tokens: [B,1]; pos: scalar current position (cache write offset)."""
        x = params["embed"][jnp.clip(tokens, 0, Vp - 1)]
        positions = pos + jnp.arange(1)
        extras = make_extras(params, {"tokens": tokens}, memory=None)
        x, caches, _ = run_stages(params, x, positions, extras, caches, pos)
        return lm_logits(params, x), caches

    def n_params(params) -> int:
        return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))

    return Model(
        config=cfg,
        init=init,
        forward=forward,
        loss=loss,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        n_params=n_params,
    )
