"""Mixture-of-Experts FF layer: token-choice top-k routing with per-row
capacity dispatch (dropping), plus optional shared experts (Qwen-MoE style).

TPU adaptation: instead of the classic (tokens, experts, capacity) one-hot
dispatch einsum (whose FLOPs/memory dwarf the expert GEMMs at LM scales) or a
global token sort (which GSPMD turns into cross-device collectives), tokens
are sorted *per batch row*: the sort/gather run along the unsharded sequence
axis, so data-parallel sharding of the batch axis needs no communication, and
expert compute is a dense grouped einsum ``(B,E,C,D) x (E,D,F)`` that the MXU
likes. Overflow beyond capacity ``C = ceil(S*k/E * capacity_factor)`` is
dropped (standard dropping-MoE semantics); the residual path carries dropped
tokens unchanged.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, swiglu_apply, swiglu_init


def moe_init(
    key,
    d_model: int,
    n_experts: int,
    expert_d_ff: int,
    n_shared_experts: int,
    shared_d_ff: int,
    dtype,
):
    ks = jax.random.split(key, 6)
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(expert_d_ff)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": (
            jax.random.normal(ks[1], (n_experts, d_model, expert_d_ff), jnp.float32)
            * scale_in
        ).astype(dtype),
        "w_up": (
            jax.random.normal(ks[2], (n_experts, d_model, expert_d_ff), jnp.float32)
            * scale_in
        ).astype(dtype),
        "w_down": (
            jax.random.normal(ks[3], (n_experts, expert_d_ff, d_model), jnp.float32)
            * scale_out
        ).astype(dtype),
    }
    if n_shared_experts > 0:
        p["shared"] = swiglu_init(ks[4], d_model, shared_d_ff, dtype)
        p["shared_gate"] = dense_init(ks[5], d_model, 1, dtype)
    return p


def _capacity(s: int, k: int, n_experts: int, capacity_factor: float) -> int:
    c = int(math.ceil(s * k * capacity_factor / n_experts))
    return max(8, ((c + 7) // 8) * 8) if s > 1 else max(1, c)


def moe_apply(
    p,
    x: jax.Array,  # [B, S, D]
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    batch_axes: Tuple[str, ...] = (),
    tp_axis: Optional[str] = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,D], aux_load_balance_loss scalar).

    ``batch_axes``: mesh axes carrying the batch dim. The sorted-dispatch
    scatter/gather is batch-parallel by construction (indices never cross
    rows), but GSPMD cannot prove that and replicates the E*C dispatch
    buffers — at qwen2-moe train scale that is a ~1 TB/device/step
    all-reduce storm (EXPERIMENTS.md §Perf iteration: qwen2-moe). Pinning
    the batch dim of every dispatch-path tensor keeps the whole MoE layer
    communication-free up to the expert GEMMs.
    """
    b, s, d = x.shape
    e, k = n_experts, top_k
    c = _capacity(s, k, e, capacity_factor)

    def pin(t, d_axis=False):
        if not batch_axes:
            return t
        from jax.sharding import PartitionSpec as P

        spec = [batch_axes] + [None] * (t.ndim - 1)
        if d_axis and tp_axis:
            spec[-1] = tp_axis  # keep the model dim sharded through combine
        return jax.lax.with_sharding_constraint(t, P(*spec))

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # [B,S,k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9
    )

    # ---- per-row sorted capacity dispatch ----
    flat_e = pin(expert_ids.reshape(b, s * k))  # [B, N] expert per (token,k)
    order = pin(jnp.argsort(flat_e, axis=-1, stable=True))  # group by expert
    sorted_e = pin(jnp.take_along_axis(flat_e, order, -1))
    # rank of each entry within its expert group
    starts = pin(
        jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(sorted_e)
    )
    rank = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, -1
    )
    keep = pin(rank < c)
    slot = pin(jnp.where(keep, sorted_e * c + rank, e * c))  # overflow slot

    token_of = pin(order // k)  # source token of each sorted entry
    rows = jnp.arange(b)[:, None]
    xg = pin(x[rows, token_of])  # [B, N, D] gathered inputs in sorted order
    buf = pin(
        jnp.zeros((b, e * c + 1, d), x.dtype).at[rows, slot].set(
            jnp.where(keep[..., None], xg, 0)
        )
    )
    xe = pin(buf[:, : e * c].reshape(b, e, c, d))

    # ---- grouped expert GEMMs (SwiGLU) ----
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"])) * jnp.einsum(
        "becd,edf->becf", xe, p["w_up"]
    )
    ye = pin(jnp.einsum("becf,efd->becd", h, p["w_down"]), d_axis=True)

    # ---- combine back (d stays model-sharded until the residual) ----
    ye_flat = pin(
        jnp.concatenate(
            [ye.reshape(b, e * c, d), jnp.zeros((b, 1, d), ye.dtype)], 1
        ),
        d_axis=True,
    )
    y_sorted = pin(ye_flat[rows, slot], d_axis=True)  # zeros where dropped
    gates_sorted = jnp.take_along_axis(gate_vals.reshape(b, s * k), order, -1)
    contrib = y_sorted * (gates_sorted * keep)[..., None].astype(ye.dtype)
    y = pin(
        jnp.zeros((b, s, d), ye.dtype).at[rows, token_of].add(contrib),
        d_axis=True,
    )

    # ---- shared experts (Qwen-MoE) ----
    if "shared" in p:
        sh = swiglu_apply(p["shared"], x)
        sg = jax.nn.sigmoid(x @ p["shared_gate"])
        y = y + sh * sg

    # ---- load-balancing aux (Switch-style) ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=(0, 1, 2)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(frac_tokens * frac_probs) * e
    return y.astype(x.dtype), aux


def moe_apply_reference(p, x, *, n_experts: int, top_k: int):
    """O(E · tokens) dense oracle: every expert on every token, masked combine.
    No capacity dropping — the dispatch path must match it when capacity is
    ample. Used by tests only."""
    b, s, d = x.shape
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("bsd,edf->besf", x, p["w_gate"])) * jnp.einsum(
        "bsd,edf->besf", x, p["w_up"]
    )
    ye = jnp.einsum("besf,efd->besd", h, p["w_down"])  # [B,E,S,D]
    weight = jnp.zeros((b, s, n_experts), jnp.float32)
    for kk in range(top_k):
        weight = weight + jax.nn.one_hot(expert_ids[..., kk], n_experts) * gate_vals[
            ..., kk : kk + 1
        ]
    y = jnp.einsum("besd,bse->bsd", ye.astype(jnp.float32), weight).astype(x.dtype)
    if "shared" in p:
        sh = swiglu_apply(p["shared"], x)
        sg = jax.nn.sigmoid(x @ p["shared_gate"])
        y = y + sh * sg
    return y
