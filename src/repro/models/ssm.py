"""State-space / recurrent mixers: Mamba-2 (SSD), xLSTM's mLSTM and sLSTM.

One chunked gated-linear-attention core (``chunked_gla``) serves both Mamba-2
and mLSTM training/prefill:

    h_t = exp(g_t) · h_{t-1} + k_t ⊗ v_t          (state: [dk, dv] per head)
    y_t = q_t · h_t

TPU adaptation: the recurrence is evaluated chunk-parallel — intra-chunk
terms become a masked, decay-weighted (Q·Kᵀ)·V product (MXU-friendly
matmuls, the "state-space duality" of the Mamba-2 paper), and only the
O(S/chunk) inter-chunk state pass is sequential (``lax.scan``). Decode is the
O(1) recurrent step. Both paths are validated against the naive sequential
scan oracle in tests; the Pallas kernel in ``repro.kernels.ssd_scan``
implements the same chunk program with explicit VMEM tiling.

All decays g are ≤ 0 (log-space), so every exponential in the chunked path is
≤ 1 — no stabilizer bookkeeping is needed.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

DEFAULT_GLA_CHUNK = 128


# ---------------------------------------------------------------------------
# chunked GLA core
# ---------------------------------------------------------------------------

def gla_scan_reference(q, k, v, g, h0=None):
    """Sequential oracle. q,k: [B,S,H,dk]; v: [B,S,H,dv]; g: [B,S,H] (log)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(hst, xs):
        qt, kt, vt, gt = xs
        hst = jnp.exp(gt)[..., None, None] * hst + jnp.einsum(
            "bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32)
        )
        yt = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), hst)
        return hst, yt

    xs = tuple(
        jnp.moveaxis(a, 1, 0)
        for a in (q, k, v, g.astype(jnp.float32))
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), hT


def chunked_gla(
    q: jax.Array,  # [B,S,H,dk]
    k: jax.Array,  # [B,S,H,dk]
    v: jax.Array,  # [B,S,H,dv]
    g: jax.Array,  # [B,S,H] log-decay per step (<= 0)
    h0: Optional[jax.Array] = None,  # [B,H,dk,dv]
    chunk: int = DEFAULT_GLA_CHUNK,
) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel evaluation of the GLA recurrence. Returns (y, h_final)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if s % chunk != 0:
        return gla_scan_reference(q, k, v, g, h0)
    n = s // chunk
    if h0 is None:
        h0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    qc = q.reshape(b, n, chunk, h, dk)
    kc = k.reshape(b, n, chunk, h, dk)
    vc = v.reshape(b, n, chunk, h, dv)
    gc = g.astype(jnp.float32).reshape(b, n, chunk, h)
    bcum = jnp.cumsum(gc, axis=2)  # decay from chunk start through t (inclusive)

    # intra-chunk: y_intra[t] = sum_{s<=t} exp(b_t - b_s) (q_t.k_s) v_s
    # (b_t - b_s <= 0 for s <= t, so all exponentials are <= 1)
    diff = bcum[:, :, :, None, :] - bcum[:, :, None, :, :]  # [B,n,T,S,H]
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])[
        None, None, :, :, None
    ]
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bnthk,bnshk->bntsh", qc.astype(jnp.float32), kc.astype(jnp.float32))
    y_intra = jnp.einsum("bntsh,bnshv->bnthv", scores * decay, vc.astype(jnp.float32))

    # per-chunk aggregated state contribution: sum_s exp(b_L - b_s) k_s v_s
    b_end = bcum[:, :, -1:, :]  # [B,n,1,H]
    k_scaled = kc.astype(jnp.float32) * jnp.exp(b_end - bcum)[..., None]
    chunk_state = jnp.einsum("bnshk,bnshv->bnhkv", k_scaled, vc.astype(jnp.float32))
    chunk_decay = jnp.exp(b_end[:, :, 0, :])  # [B,n,H] total chunk decay

    # inter-chunk scan: h_{c} = chunk_decay_c * h_{c-1} + chunk_state_c
    def step(hst, xs):
        cs, cd = xs  # [B,H,dk,dv], [B,H]
        h_in = hst
        hst = cd[..., None, None] * hst + cs
        return hst, h_in

    hT, h_starts = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_starts = jnp.moveaxis(h_starts, 0, 1)  # [B,n,H,dk,dv] state entering chunk

    # inter-chunk contribution: y_inter[t] = exp(b_t) q_t . h_start
    q_scaled = qc.astype(jnp.float32) * jnp.exp(bcum)[..., None]
    y_inter = jnp.einsum("bnthk,bnhkv->bnthv", q_scaled, h_starts)

    y = (y_intra + y_inter).reshape(b, s, h, dv)
    return y.astype(v.dtype), hT


def gla_decode_step(q, k, v, g, h):
    """One recurrent step. q,k: [B,H,dk]; v: [B,H,dv]; g: [B,H]; h: [B,H,dk,dv]."""
    h = jnp.exp(g.astype(jnp.float32))[..., None, None] * h + jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), h)
    return y.astype(v.dtype), h


# ---------------------------------------------------------------------------
# depthwise causal conv (Mamba front conv)
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """x: [B,S,C]; w: [K,C] depthwise; returns [B,S,C] (causal, left-padded)."""
    kk = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (kk - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(kk)
    )
    return out + bias


def causal_conv_step(x_t, conv_cache, w, bias):
    """x_t: [B,C]; conv_cache: [B,K-1,C] (previous inputs). Returns (y, cache)."""
    full = jnp.concatenate([conv_cache, x_t[:, None, :]], 1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", full, w) + bias
    return y, full[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-2 mixer
# ---------------------------------------------------------------------------

class Mamba2Dims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    conv_k: int

    @staticmethod
    def make(d_model: int, d_state: int, expand: int = 2, head_dim: int = 64, conv_k: int = 4):
        d_inner = expand * d_model
        return Mamba2Dims(
            d_model, d_inner, d_inner // head_dim, head_dim, d_state, conv_k
        )

    @property
    def conv_channels(self):
        return self.d_inner + 2 * self.d_state


def mamba2_init(key, dims: Mamba2Dims, dtype):
    """Separately-shardable projections (TP adaptation, DESIGN.md §5).

    The reference implementation uses one concatenated ``in_proj`` whose
    output mixes head-sharded (z, x), replicated (B, C) and per-head (dt)
    segments — unshardable as a single matrix. Splitting it (same math,
    same FLOPs) lets z/x column-shard and out_proj row-shard over the
    ``model`` axis: Mamba compute scales with TP instead of being
    replicated (EXPERIMENTS.md §Perf iteration: zamba2).
    """
    ks = jax.random.split(key, 8)
    # dt bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 reference init)
    u = jax.random.uniform(ks[6], (dims.n_heads,), jnp.float32)
    dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "z_proj": dense_init(ks[0], dims.d_model, dims.d_inner, dtype),
        "x_proj": dense_init(ks[1], dims.d_model, dims.d_inner, dtype),
        "bc_proj": dense_init(ks[2], dims.d_model, 2 * dims.d_state, dtype),
        "dt_proj": dense_init(ks[3], dims.d_model, dims.n_heads, dtype),
        "conv_w_x": (
            jax.random.normal(ks[4], (dims.conv_k, dims.d_inner), jnp.float32)
            / math.sqrt(dims.conv_k)
        ).astype(dtype),
        "conv_b_x": jnp.zeros((dims.d_inner,), dtype),
        "conv_w_bc": (
            jax.random.normal(ks[5], (dims.conv_k, 2 * dims.d_state), jnp.float32)
            / math.sqrt(dims.conv_k)
        ).astype(dtype),
        "conv_b_bc": jnp.zeros((2 * dims.d_state,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, dims.n_heads, dtype=jnp.float32)
        ),
        "D": jnp.ones((dims.n_heads,), jnp.float32),
        "dt_bias": dt_bias,
        "norm_g": jnp.ones((dims.d_inner,), dtype),
        "out_proj": dense_init(ks[7], dims.d_inner, dims.d_model, dtype),
    }


def _mamba2_split(p, x, dims: Mamba2Dims):
    """(z, x_in, bc, dt_raw) from the separate projections."""
    z = x @ p["z_proj"]
    xin = x @ p["x_proj"]
    bc = x @ p["bc_proj"]
    dt_raw = x @ p["dt_proj"]
    return z, xin, bc, dt_raw


def mamba2_apply(
    p,
    x: jax.Array,  # [B,S,D]
    dims: Mamba2Dims,
    h0: Optional[jax.Array] = None,
    conv0: Optional[Tuple[jax.Array, jax.Array]] = None,
    chunk: int = DEFAULT_GLA_CHUNK,
    eps: float = 1e-5,
):
    """Training/prefill path. Returns (y, (h_final, (conv_x, conv_bc)))."""
    b, s, _ = x.shape
    z, xraw, bcraw, dt_raw = _mamba2_split(p, x, dims)

    def conv_branch(raw, w, bias, cache):
        if cache is not None:
            xp = jnp.concatenate([cache.astype(raw.dtype), raw], 1)
            out = causal_conv(xp, w, bias)[:, cache.shape[1] :]
        else:
            out = causal_conv(raw, w, bias)
        new_cache = (
            jnp.concatenate([cache.astype(raw.dtype), raw], 1)[:, -(dims.conv_k - 1) :]
            if cache is not None
            else _last_k(raw, dims.conv_k - 1)
        )
        return jax.nn.silu(out), new_cache

    cx0, cbc0 = conv0 if conv0 is not None else (None, None)
    xin_flat, new_cx = conv_branch(xraw, p["conv_w_x"], p["conv_b_x"], cx0)
    bc, new_cbc = conv_branch(bcraw, p["conv_w_bc"], p["conv_b_bc"], cbc0)
    B, C = jnp.split(bc, 2, axis=-1)
    xin = xin_flat.reshape(b, s, dims.n_heads, dims.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H] negative
    g = dt * A  # log decay <= 0

    # broadcast single-group B,C over heads; dt absorbed into k
    k = B[:, :, None, :] * dt[..., None]  # [B,S,H,N]
    q = jnp.broadcast_to(
        C[:, :, None, :], (b, s, dims.n_heads, dims.d_state)
    )
    y, hT = chunked_gla(q, k.astype(jnp.float32), xin, g, h0, chunk)
    y = y + xin * p["D"][None, None, :, None]
    y = y.reshape(b, s, dims.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_g"], eps)
    return (y @ p["out_proj"]).astype(x.dtype), (hT, (new_cx, new_cbc))


def _last_k(x, k):
    b, s, c = x.shape
    pad = jnp.zeros((b, max(k - s, 0), c), x.dtype)
    return jnp.concatenate([pad, x], 1)[:, -k:]


def mamba2_decode(p, x_t, dims: Mamba2Dims, state, eps: float = 1e-5):
    """One-token step. x_t: [B,D]; state = (h [B,H,N,hd], (conv_x, conv_bc))."""
    h, (conv_x, conv_bc) = state
    b = x_t.shape[0]
    z, xraw, bcraw, dt_raw = _mamba2_split(p, x_t[:, None, :], dims)
    z, xraw, bcraw, dt_raw = z[:, 0], xraw[:, 0], bcraw[:, 0], dt_raw[:, 0]
    xin_flat, conv_x = causal_conv_step(
        xraw, conv_x.astype(xraw.dtype), p["conv_w_x"], p["conv_b_x"]
    )
    bc, conv_bc = causal_conv_step(
        bcraw, conv_bc.astype(bcraw.dtype), p["conv_w_bc"], p["conv_b_bc"]
    )
    xin_flat = jax.nn.silu(xin_flat)
    bc = jax.nn.silu(bc)
    B, C = jnp.split(bc, 2, axis=-1)
    xin = xin_flat.reshape(b, dims.n_heads, dims.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    g = dt * A
    k = B[:, None, :] * dt[..., None]  # [B,H,N]
    q = jnp.broadcast_to(C[:, None, :], (b, dims.n_heads, dims.d_state))
    y, h = gla_decode_step(q, k, xin, g, h)
    y = y + xin * p["D"][None, :, None]
    y = y.reshape(b, dims.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_g"], eps)
    return (y @ p["out_proj"]).astype(x_t.dtype), (h, (conv_x, conv_bc))


def mamba2_state_shape(dims: Mamba2Dims, batch: int):
    return (
        (batch, dims.n_heads, dims.d_state, dims.head_dim),
        (
            (batch, dims.conv_k - 1, dims.d_inner),
            (batch, dims.conv_k - 1, 2 * dims.d_state),
        ),
    )


# ---------------------------------------------------------------------------
# mLSTM (xLSTM) — gated linear attention form
# ---------------------------------------------------------------------------

class MLstmDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int

    @staticmethod
    def make(d_model: int, n_heads: int, expand: int = 2):
        d_inner = expand * d_model
        return MLstmDims(d_model, d_inner, n_heads, d_inner // n_heads)


def mlstm_init(key, dims: MLstmDims, dtype):
    ks = jax.random.split(key, 7)
    di = dims.d_inner
    return {
        "up_proj": dense_init(ks[0], dims.d_model, 2 * di, dtype),
        "wq": dense_init(ks[1], di, di, dtype),
        "wk": dense_init(ks[2], di, di, dtype),
        "wv": dense_init(ks[3], di, di, dtype),
        "w_if": dense_init(ks[4], di, 2 * dims.n_heads, jnp.float32),
        # forget-gate bias init > 0 -> long memory at init
        "b_if": jnp.concatenate(
            [jnp.zeros(dims.n_heads), 3.0 * jnp.ones(dims.n_heads)]
        ).astype(jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
        "down_proj": dense_init(ks[5], di, dims.d_model, dtype),
    }


def _mlstm_qkvg(p, xin, dims: MLstmDims):
    b, s, _ = xin.shape
    h, hd = dims.n_heads, dims.head_dim
    q = (xin @ p["wq"]).reshape(b, s, h, hd) / math.sqrt(hd)
    k = (xin @ p["wk"]).reshape(b, s, h, hd)
    v = (xin @ p["wv"]).reshape(b, s, h, hd)
    if_raw = xin.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_raw, f_raw = jnp.split(if_raw, 2, axis=-1)  # [B,S,H]
    i_gate = jax.nn.sigmoid(i_raw)
    g = jax.nn.log_sigmoid(f_raw)  # log decay <= 0
    return q, k * i_gate[..., None], v, g


def mlstm_apply(
    p,
    x: jax.Array,
    dims: MLstmDims,
    state=None,
    chunk: int = DEFAULT_GLA_CHUNK,
    eps: float = 1e-5,
):
    """Returns (y, (h_final, n_final)). state: (h [B,H,hd,hd], n [B,H,hd,1])."""
    b, s, _ = x.shape
    up = x @ p["up_proj"]
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, g = _mlstm_qkvg(p, xin, dims)
    h0, n0 = state if state is not None else (None, None)
    y, hT = chunked_gla(q, k, v, g, h0, chunk)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    nq, nT = chunked_gla(q, k, ones, g, n0, chunk)  # denominator q.n_t
    denom = jnp.maximum(jnp.abs(nq.astype(jnp.float32)), 1.0)
    y = (y.astype(jnp.float32) / denom).astype(x.dtype)
    y = y.reshape(b, s, dims.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_g"], eps)
    return y @ p["down_proj"], (hT, nT)


def mlstm_decode(p, x_t, dims: MLstmDims, state, eps: float = 1e-5):
    h, n = state
    b = x_t.shape[0]
    up = x_t[:, None, :] @ p["up_proj"]
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, g = _mlstm_qkvg(p, xin, dims)
    q, k, v, g = q[:, 0], k[:, 0], v[:, 0], g[:, 0]
    y, h = gla_decode_step(q, k, v, g, h)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    nq, n = gla_decode_step(q, k, ones, g, n)
    denom = jnp.maximum(jnp.abs(nq.astype(jnp.float32)), 1.0)
    y = (y.astype(jnp.float32) / denom).astype(x_t.dtype)
    y = y.reshape(b, dims.d_inner)
    y = rms_norm(y * jax.nn.silu(z[:, 0]), p["norm_g"], eps)
    return y @ p["down_proj"], (h, n)


def mlstm_state_shape(dims: MLstmDims, batch: int):
    return (
        (batch, dims.n_heads, dims.head_dim, dims.head_dim),
        (batch, dims.n_heads, dims.head_dim, 1),
    )


# ---------------------------------------------------------------------------
# sLSTM (xLSTM) — strictly sequential scalar-memory cell
# ---------------------------------------------------------------------------

class SLstmDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int

    @staticmethod
    def make(d_model: int, n_heads: int, expand: int = 1):
        d_inner = expand * d_model
        return SLstmDims(d_model, d_inner, n_heads, d_inner // n_heads)


def slstm_init(key, dims: SLstmDims, dtype):
    ks = jax.random.split(key, 4)
    di = dims.d_inner
    return {
        "w_in": dense_init(ks[0], dims.d_model, 4 * di, dtype),
        # block-diagonal recurrent weights, one block per head
        "r": (
            jax.random.normal(
                ks[1], (dims.n_heads, dims.head_dim, 4 * dims.head_dim), jnp.float32
            )
            / math.sqrt(dims.head_dim)
        ).astype(dtype),
        "b": jnp.concatenate(
            [jnp.zeros(3 * di), 3.0 * jnp.ones(di)]  # forget bias > 0
        ).astype(jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, dims.d_model, dtype),
    }


class SLstmState(NamedTuple):
    c: jax.Array  # [B, di]
    n: jax.Array  # [B, di]
    m: jax.Array  # [B, di]
    h: jax.Array  # [B, di]


def slstm_zero_state(dims: SLstmDims, batch: int) -> SLstmState:
    z = jnp.zeros((batch, dims.d_inner), jnp.float32)
    return SLstmState(z, z, z - 10.0, z)


def _slstm_cell(p, dims: SLstmDims, x_gates_t, st: SLstmState):
    """x_gates_t: [B, 4*di] (input contribution). Stabilized exp gating."""
    b = st.h.shape[0]
    hh = st.h.reshape(b, dims.n_heads, dims.head_dim).astype(p["r"].dtype)
    rec = jnp.einsum("bhd,hdf->bhf", hh, p["r"]).reshape(b, 4 * dims.d_inner)
    gates = x_gates_t.astype(jnp.float32) + rec.astype(jnp.float32) + p["b"]
    z_raw, i_raw, o_raw, f_raw = jnp.split(gates, 4, axis=-1)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    f_log = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(f_log + st.m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(f_log + st.m - m_new)
    c = f_p * st.c + i_p * z
    n = f_p * st.n + i_p
    h = o * c / jnp.maximum(n, 1e-6)
    return SLstmState(c, n, m_new, h)


def slstm_apply(p, x, dims: SLstmDims, state: Optional[SLstmState] = None, eps=1e-5):
    b, s, _ = x.shape
    st = state if state is not None else slstm_zero_state(dims, b)
    x_gates = x @ p["w_in"]  # [B,S,4di]

    def step(st, xg_t):
        st = _slstm_cell(p, dims, xg_t, st)
        return st, st.h

    stT, hs = jax.lax.scan(step, st, jnp.moveaxis(x_gates, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # [B,S,di]
    y = rms_norm(y, p["norm_g"], eps)
    return y @ p["out_proj"], stT


def slstm_decode(p, x_t, dims: SLstmDims, state: SLstmState, eps=1e-5):
    xg = x_t @ p["w_in"]
    st = _slstm_cell(p, dims, xg, state)
    y = rms_norm(st.h.astype(x_t.dtype), p["norm_g"], eps)
    return y @ p["out_proj"], st
