"""Public model API: build_model(config) -> Model. Placeholder populated by
repro.models.transformer; see that module."""
from repro.models.transformer import Model, build_model  # noqa: F401
