"""Core layers: norms, RoPE, GQA attention (with online-softmax chunked path
and KV caches), SwiGLU/GELU MLPs. Raw-pytree params, jnp-only.

The chunked attention path (`attention_chunked`) is the XLA twin of the
Pallas flash kernel in ``repro.kernels.flash_attention`` — same online
softmax algorithm, used for long-sequence prefill so the working set stays
O(chunk) instead of O(S²).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_CHUNK = 1024


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, fan_in: int, fan_out: int, dtype) -> jax.Array:
    scale = jnp.sqrt(1.0 / fan_in)
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return (x32 * scale).astype(dt) * gain


def layer_norm(x, gain, bias, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * gain + bias


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B,S,K,hd] -> [B,S,K*n_rep,hd] (GQA expansion)."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, kh, n_rep, hd)
    ).reshape(b, s, kh * n_rep, hd)


def attention_naive(
    q: jax.Array,  # [B,Sq,H,hd]
    k: jax.Array,  # [B,Sk,K,hd]
    v: jax.Array,  # [B,Sk,K,hd]
    causal: bool,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    """Materialized-scores attention (oracle / short sequences / decode)."""
    h, kh = q.shape[2], k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool,
    q_offset: jax.Array | int = 0,
    chunk: int = DEFAULT_CHUNK,
) -> jax.Array:
    """Online-softmax attention, scanning KV in chunks (flash-style in XLA).

    Memory O(Sq·chunk) instead of O(Sq·Sk); numerically identical to
    attention_naive (same fp32 accumulation), validated in tests.
    """
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    if sk % chunk != 0:
        return attention_naive(q, k, v, causal, q_offset)
    n_rep = h // kh
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kc = k.reshape(b, sk // chunk, chunk, kh, hd)
    vc = v.reshape(b, sk // chunk, chunk, kh, hd)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, xs):
        acc, m, l = carry  # [B,H,Sq,hd], [B,H,Sq], [B,H,Sq]
        kb, vb, c_idx = xs  # [B,chunk,K,hd] ×2, scalar
        kb = _repeat_kv(kb, n_rep)
        vb = _repeat_kv(vb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        if causal:
            kpos = c_idx * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, -1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body,
        (acc0, m0, l0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.arange(sk // chunk),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)  # [B,Sq,H,hd]


@dataclasses.dataclass(frozen=True)
class AttnParams:
    pass  # params are plain dicts; this namespace documents the layout


def attn_init(key, d_model, n_heads, n_kv_heads, head_dim, qk_norm, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def attn_qkv(p, x, n_heads, n_kv_heads, head_dim, positions, theta, qk_norm, eps):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    if theta:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attn_apply(
    p,
    x,
    *,
    n_heads,
    n_kv_heads,
    head_dim,
    positions,
    theta,
    qk_norm=False,
    eps=1e-5,
    causal=True,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    attn_impl: str = "auto",
    chunk: int = DEFAULT_CHUNK,
):
    """Self-attention with optional KV cache.

    cache: (k_cache, v_cache) [B, S_max, K, hd]; cache_pos: write offset
    (scalar). Returns (out [B,S,D'], new_cache).
    """
    b, s, _ = x.shape
    q, k, v = attn_qkv(
        p, x, n_heads, n_kv_heads, head_dim, positions, theta, qk_norm, eps
    )
    if cache is not None:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cache_pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cache_pos, 1)
        s_max = kc.shape[1]
        # mask out cache slots beyond the current position
        valid = jnp.arange(s_max) < (cache_pos + s)
        k_eff = jnp.where(valid[None, :, None, None], kc, 0)
        v_eff = jnp.where(valid[None, :, None, None], vc, 0)
        # logits for invalid slots masked via causal offset (cache_pos + row)
        out = _attend(
            q, k_eff, v_eff, True, cache_pos, attn_impl, chunk, kv_valid=valid
        )
        new_cache = (kc, vc)
    else:
        out = _attend(q, k, v, causal, 0, attn_impl, chunk)
        new_cache = None
    out = out.reshape(b, s, n_heads * head_dim)
    return out @ p["wo"], new_cache


def _attend(q, k, v, causal, q_offset, impl, chunk, kv_valid=None):
    if kv_valid is not None:
        # fold validity into a causal-style bound: invalid slots have key
        # position >= everything (they are zeros; mask via big-negative below)
        pass
    sq, sk = q.shape[1], k.shape[1]
    if impl == "naive":
        out = _masked_naive(q, k, v, causal, q_offset, kv_valid)
    elif impl == "chunked":
        out = _masked_chunked(q, k, v, causal, q_offset, chunk, kv_valid)
    else:  # auto
        if sq == 1 or sk <= 2 * chunk:
            out = _masked_naive(q, k, v, causal, q_offset, kv_valid)
        else:
            out = _masked_chunked(q, k, v, causal, q_offset, chunk, kv_valid)
    return out


def _masked_naive(q, k, v, causal, q_offset, kv_valid):
    h, kh = q.shape[2], k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
    if kv_valid is not None:
        mask = mask & kv_valid[None, :]
    logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _masked_chunked(q, k, v, causal, q_offset, chunk, kv_valid):
    if kv_valid is None and k.shape[1] % chunk == 0:
        return attention_chunked(q, k, v, causal, q_offset, chunk)
    if kv_valid is not None and k.shape[1] % chunk == 0:
        return _chunked_with_valid(q, k, v, causal, q_offset, chunk, kv_valid)
    return _masked_naive(q, k, v, causal, q_offset, kv_valid)


def _chunked_with_valid(q, k, v, causal, q_offset, chunk, kv_valid):
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    n_rep = h // kh
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kc = k.reshape(b, sk // chunk, chunk, kh, hd)
    vc = v.reshape(b, sk // chunk, chunk, kh, hd)
    validc = kv_valid.reshape(sk // chunk, chunk)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, xs):
        acc, m, l = carry
        kb, vb, valb, c_idx = xs
        kb = _repeat_kv(kb, n_rep)
        vb = _repeat_kv(vb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        kpos = c_idx * chunk + jnp.arange(chunk)
        mask = valb[None, :]
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, -1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body,
        (acc0, m0, l0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            validc,
            jnp.arange(sk // chunk),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def gelu_mlp_init(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp_apply(p, x):
    return jax.nn.gelu(x @ p["w_in"] + p["b_in"]) @ p["w_out"] + p["b_out"]
