"""Parameter/activation sharding rules for the production mesh.

Mesh axes: ``("data","model")`` single-pod (16x16) or ``("pod","data","model")``
multi-pod (2x16x16). Batch shards over ("pod","data"); tensor-parallel dims
over "model" (Megatron pairing: column-parallel then row-parallel, so each
block needs one reduce); with ``cfg.fsdp`` the complementary weight dim also
shards over "data" (ZeRO-3-style), which is what lets grok-1-314b fit HBM.

Rules are name/shape driven over the param pytree (stacked leading stage axes
are skipped). Any dim that does not divide its mesh axis falls back to
replication (e.g. glm4's 2 KV heads vs the 16-way model axis).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes used for batch/data parallelism ('pod' folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _div(n: int, mesh: Mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        size = _axis_size(mesh, axis)
    return n % size == 0 and n >= size


def _maybe(n: int, mesh: Mesh, axis):
    return axis if _div(n, mesh, axis) else None


# 2D weight rule: (in_dim -> fsdp/'data', out_dim -> 'model') or transposed
def _matmul_spec(shape, mesh, cfg, model_dim: int, fsdp_dim: Optional[int]):
    """Build a PartitionSpec for an nD weight; only the trailing 2 dims (or
    named dims) are sharded, leading stage-stack dims replicate."""
    spec = [None] * len(shape)
    if model_dim is not None and _div(shape[model_dim], mesh, "model"):
        spec[model_dim] = "model"
    if cfg.fsdp and fsdp_dim is not None and _div(shape[fsdp_dim], mesh, "data"):
        spec[fsdp_dim] = "data"
    return P(*spec)


def zero_sp_param_spec(cfg: ArchConfig, mesh: Mesh, shape) -> P:
    """fsdp_sp layout for matmul weights: contraction dim (-2) over 'model'
    (the weight is all-gathered per layer — ZeRO-style — instead of
    all-reducing full activations), optional ZeRO over 'data' on dim -1.
    Activations stay (batch over data, sequence over model); GSPMD then
    inserts only the cheap GQA KV all-gathers inside attention."""
    spec = [None] * len(shape)
    if _div(shape[-2], mesh, "model"):
        spec[-2] = "model"
    if cfg.fsdp and _div(shape[-1], mesh, "data"):
        spec[-1] = "data"
    return P(*spec)


def param_spec(cfg: ArchConfig, mesh: Mesh, path: str, shape) -> P:
    """PartitionSpec for one parameter leaf, from its tree path + shape."""
    nd = len(shape)
    name_ = path.split("/")[-1]
    if (
        cfg.sharding_mode == "fsdp_sp"
        and nd >= 2
        and name_ not in ("embed", "lm_head")
        and "mixer" not in path
        and name_ != "r"
    ):
        return zero_sp_param_spec(cfg, mesh, shape)
    last, prev = nd - 1, nd - 2

    def ms(model_dim, fsdp_dim):
        return _matmul_spec(shape, mesh, cfg, model_dim, fsdp_dim)

    name = path.split("/")[-1]
    if nd <= 1:
        return P()
    # embeddings / unembedding
    if name == "embed":
        return ms(0, 1)  # (Vp, D): vocab over model
    if name == "lm_head":
        return ms(last, prev)  # (D, Vp): vocab over model
    # attention projections
    if name in ("wq", "wk", "wv"):
        return ms(last, prev)
    if name == "wo":
        return ms(prev, last)
    # dense MLPs (swiglu / gelu): column then row parallel
    if name in ("w_gate", "w_up", "w_in"):
        return ms(last, prev)
    if "moe" in path and name == "w_down" and not cfg.fsdp:
        # MoE down-projection: model on the OUTPUT dim. Row-parallel would
        # all-reduce the padded [B,E,C,D] capacity buffer (~5x the token
        # volume at top-4/cf1.25); output-sharding keeps the combine
        # d-sharded and defers to one small token-space all-gather at the
        # residual (EXPERIMENTS.md §Perf: qwen2-moe iteration 6).
        # NOT under fsdp: there the contraction dim would be sharded over
        # different axes on the two operands (model on h, data on w_down)
        # and GSPMD gathers the full-d_ff expert activations — measured
        # 2.7 TB/step on grok-1 (§Perf iteration 7)
        return ms(last, prev)
    if name in ("w_down", "w_out"):
        return ms(prev, last)
    # MoE: experts stay replicated on the expert dim (rarely divides 16);
    # per-expert matrices shard like dense MLPs on their trailing dims
    if name == "router":
        return P()
    # mamba2 mixer (separate projections, ssm.mamba2_init): z/x column-
    # parallel, conv-x channels + norm gain follow, out_proj row-parallel —
    # heads shard over 'model' end-to-end (EXPERIMENTS §Perf: zamba2).
    # B/C/dt projections and per-head scalars are small -> replicated.
    if name in ("z_proj", "x_proj"):
        return ms(last, prev)
    if name == "conv_w_x":
        return ms(last, None)
    if name in ("bc_proj", "dt_proj", "conv_w_bc"):
        return P()
    if "mixer" in path and name == "out_proj":
        return ms(prev, last)
    # xlstm mixers: recurrent block-diagonal weights stay replicated (sLSTM
    # heads = 4, below the 16-way model axis; documented in DESIGN.md)
    if "mixer" in path or name in ("r",):
        return P()
    if name in ("up_proj",):
        return ms(last, prev)
    if name in ("down_proj", "out_proj"):
        return ms(prev, last)
    return P()


def params_shardings(cfg: ArchConfig, mesh: Mesh, params_shapes: PyTree) -> PyTree:
    """NamedSharding tree matching an eval_shape of model.init."""

    def leaf(path, x):
        pstr = "/".join(
            getattr(k, "key", getattr(k, "name", str(getattr(k, "idx", k))))
            for k in path
        )
        return NamedSharding(mesh, param_spec(cfg, mesh, pstr, x.shape))

    return jax.tree_util.tree_map_with_path(leaf, params_shapes)


def batch_spec(mesh: Mesh, ndim: int, shard_seq_axis: Optional[int] = None) -> P:
    """Batch tensors: axis 0 over ('pod','data') when divisible."""
    spec = [None] * ndim
    spec[0] = data_axes(mesh)
    if shard_seq_axis is not None:
        spec[0] = None
        spec[shard_seq_axis] = data_axes(mesh)
    return P(*spec)


def batch_shardings(
    mesh: Mesh,
    batch_shapes: PyTree,
    batch_size: int,
    seq_over_model: bool = False,
) -> PyTree:
    """Shard batch dim over data axes; batch=1 (long-context) falls back to
    replicated batch (sequence sharding is applied to the cache instead).

    ``seq_over_model`` (the fsdp_sp layout): additionally shard the sequence
    axis (dim 1) over the model axis, making every activation tensor
    sequence-parallel — GSPMD then all-gathers the (small, GQA) KV heads
    inside attention instead of all-reducing full activations per layer.
    """
    dp = int(np.prod([_axis_size(mesh, a) for a in data_axes(mesh)]))

    def leaf(x):
        if x.ndim >= 1 and x.shape[0] == batch_size and batch_size % dp == 0:
            spec = [None] * x.ndim
            spec[0] = data_axes(mesh)
            if (
                seq_over_model
                and x.ndim >= 2
                and "model" in mesh.shape
                and x.shape[1] % mesh.shape["model"] == 0
            ):
                spec[1] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(leaf, batch_shapes)


def cache_shardings(
    cfg: ArchConfig, mesh: Mesh, cache_shapes: PyTree, batch_size: int, seq_len: int
) -> PyTree:
    """KV caches / SSM states.

    Layout is (stage_count, B, S, K, hd) for attention KV. Batch shards over
    data when divisible; for batch=1 long-context decode the *sequence* axis
    shards over data instead (context parallelism), and the model axis shards
    KV heads when divisible.
    """
    dp = int(np.prod([_axis_size(mesh, a) for a in data_axes(mesh)]))
    dax = data_axes(mesh)

    def leaf(x):
        spec = [None] * x.ndim
        # find batch axis: first axis == batch_size after the stage-stack axis
        baxis = None
        for i, s in enumerate(x.shape[:3]):
            if s == batch_size:
                baxis = i
                break
        if baxis is not None and batch_size % dp == 0 and batch_size >= dp:
            spec[baxis] = dax
        elif baxis is not None:
            # context parallel: shard the (long) sequence axis
            for i in range(baxis + 1, x.ndim):
                if x.shape[i] == seq_len and seq_len % dp == 0:
                    spec[i] = dax
                    break
        # shard KV heads over model where divisible (axis sized n_kv_heads)
        for i in range(x.ndim - 2, x.ndim):
            if (
                i > (baxis or 0)
                and x.shape[i] == cfg.n_kv_heads
                and _div(cfg.n_kv_heads, mesh, "model")
                and spec[i] is None
            ):
                spec[i] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(leaf, cache_shapes)


def replicated(mesh: Mesh, shapes: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), shapes)
