"""Declarative experiment layer over the one-compile grid engine.

An :class:`Experiment` is a frozen, JSON-round-trippable spec of a full
study — workload, platform, a scheduler x timeout grid, replications, output
directory — and :func:`run` evaluates the *whole grid* as ONE compiled
program per replication via ``engine.sweep``'s traced policy axis
(core/SEMANTICS.md §Traced policy axis). This is the paper's
"JSON-configurable, reproducible experiments" layer (§2.3.2/2.3.3), scaled
to grids: the Figs. 4/5 six-scheduler comparison is one program, not six.

    from repro import experiments
    exp = experiments.Experiment(
        name="fig45",
        workload={"preset": "nasa_ipsc", "n_jobs": 400},
        platform=128,
        schedulers=("EASY PSUS", "EASY PSAS", "EASY PSAS+IPM"),
        timeouts=(300, 900, 1800),
    )
    result = experiments.run(exp)     # result.n_compiles == 1
    exp.save("exp.json")              # and back: Experiment.load("exp.json")

CLI: ``python -m repro.launch.sim --experiment exp.json``.
"""
from repro.experiments.spec import (
    Experiment,
    check_unknown_keys,
    resolve_platform,
    resolve_workload,
)
from repro.experiments.runner import (
    ExperimentResult,
    StreamingRun,
    run,
    run_file,
)

__all__ = [
    "Experiment",
    "ExperimentResult",
    "StreamingRun",
    "check_unknown_keys",
    "resolve_platform",
    "resolve_workload",
    "run",
    "run_file",
]
