"""Evaluate an :class:`Experiment`: the whole grid, one compiled program.

``run(experiment)`` resolves the spec, builds the scheduler x timeout
scenario grid, and pushes it through ``engine.sweep`` — the traced policy
axis makes the full grid (all replications included) exactly ONE compiled
XLA program. A single-point grid (1 scheduler x 1 timeout) skips the
superset program entirely and takes ``engine.simulate``'s statically
specialized path instead: the policy flags are closure constants, dead
rules are DCE'd, and the compile is cached across replications/reruns
(core/SEMANTICS.md §Static specialization) — rows are bit-exact either
way. Results come back as a flat rows table (one dict per grid
point per replication) and, when ``experiment.out`` is set, are written as
a deterministic ``metrics.json`` (byte-identical across reruns of the same
spec — the golden-file anchor in ``tests/test_experiments.py``) plus a
``rows.csv`` for spreadsheet use.

``run(..., stream=True)`` swaps the barrier for a :class:`StreamingRun`
iterator of completed row-chunks (core/SEMANTICS.md §Device-sharded
sweeps): the grid is chunked (``chunk_scenarios``), chunk ``k+1`` is
dispatched through ``engine.sweep_async`` before chunk ``k``'s host
transfer drains, and ``metrics.json``/``rows.csv`` are rewritten after
every chunk — incremental progress on disk, yet the final files are
byte-identical to the blocking path. ``devices`` shards each launch's
scenario axis across local devices (bit-exact either way).
"""
from __future__ import annotations

import csv
import dataclasses
import json
import os
import time
import warnings
from collections import deque
from typing import Any, Iterator, Optional, Tuple

from repro.core import engine
from repro.experiments.spec import Experiment, resolve_platform, resolve_workload


@dataclasses.dataclass(frozen=True)
class ExperimentResult:
    """Rows are scheduler-major x timeout [x forecast] [x platform] x
    replication, in grid order (``forecast`` / ``platform`` columns appear
    when the spec has those axes).

    ``n_compiles`` is the compiled-program count of the grid's jitted
    driver (the one-compile guarantee: 1, or None on JAX versions without
    cache introspection). ``wall_s`` is host wall time for all sweeps —
    reported, never written into metrics.json (determinism).
    """

    experiment: Experiment
    rows: Tuple[dict, ...]
    n_compiles: Optional[int]
    wall_s: float

    @property
    def jobs_per_s(self) -> float:
        sim_jobs = sum(r["n_jobs"] for r in self.rows)
        return sim_jobs / self.wall_s if self.wall_s > 0 else 0.0

    def table(self) -> str:
        """A compact fixed-width text table (CLI output)."""
        cols = ["scheduler", "timeout", "replication", "total_energy_kwh",
                "wasted_energy_kwh", "mean_wait_s", "utilization"]
        if any("platform" in r for r in self.rows):
            cols.insert(2, "platform")
        if any("forecast" in r for r in self.rows):
            cols.insert(2, "forecast")
        lines = [" ".join(f"{c:>18s}" for c in cols)]
        for r in self.rows:
            cells = []
            for c in cols:
                v = r.get(c)
                cells.append(
                    f"{v:>18.3f}" if isinstance(v, float) else f"{str(v):>18s}"
                )
            lines.append(" ".join(cells))
        return "\n".join(lines)


def _metrics_payload(result: ExperimentResult) -> dict:
    return {
        "experiment": dataclasses.asdict(result.experiment),
        "n_compiles": result.n_compiles,
        "rows": list(result.rows),
    }


def _engine_config_with_rl(experiment: Experiment, plat):
    """The shared static EngineConfig; RL scheduler labels get the
    checkpointed in-graph controller from ``experiment.rl`` attached.

    The controller is static trace structure (core/SEMANTICS.md §Traced vs
    static), shared by every grid point: non-RL rows run it with rule 8
    traced off, and all RL labels must therefore name ONE policy stack.
    """
    from repro.core.policy import RLController, from_label

    cfg = experiment.engine_config()
    rl_stacks = {
        label: pol
        for label in experiment.schedulers
        for _, pol in [from_label(label)]
        if isinstance(pol, RLController)
    }
    if not rl_stacks:
        if experiment.rl is not None:
            raise ValueError(
                "experiment declares an rl checkpoint block but no RL "
                f"scheduler label is in the grid ({list(experiment.schedulers)}); "
                "add an 'RL' / 'RL:groups' / 'RL:dvfs' label or drop the "
                "rl entry"
            )
        return cfg
    if len(set(rl_stacks.values())) > 1:
        raise ValueError(
            "an experiment grid shares ONE in-graph RL controller (static "
            "trace structure); scheduler labels "
            f"{sorted(rl_stacks)} name different RL stacks — split them "
            "into separate experiments"
        )
    if not experiment.rl or "checkpoint" not in experiment.rl:
        raise ValueError(
            f"RL scheduler label(s) {sorted(rl_stacks)} need an "
            'rl: {"checkpoint": <dir>} experiment entry (a policy saved by '
            "training.checkpoint.save_policy)"
        )
    # lazy import: repro.launch.sim imports repro.experiments at module top
    from repro.launch.sim import _resolve_rl_policy

    pol = next(iter(rl_stacks.values()))
    pol, rl = _resolve_rl_policy(pol, {"rl": dict(experiment.rl)}, plat)
    return dataclasses.replace(
        cfg,
        policy=pol,
        rl_decision_interval=rl.get("decision_interval"),
    )


def _run_single(plat, wl, scenario, cfg):
    """One grid point through the specialized single-config program.

    The scenario dict is the grid() shape ({scheduler, timeout[, platform
    -> resolved PlatformSpec]}); the label's policy point is folded into
    the trace as closure constants (``engine.simulate`` specialization),
    bit-exact with the traced sweep row it replaces. Returns
    (SimMetrics, n_compiles-of-the-cached-program).
    """
    from repro.core.metrics import metrics_from_state
    from repro.core.policy import RLController, from_label

    base, pol = from_label(scenario["scheduler"])
    if isinstance(pol, RLController):
        # cfg.policy carries the checkpointed in-graph controller attached
        # by _engine_config_with_rl (shared static trace structure)
        pol = cfg.policy
    plat_i = scenario.get("platform", plat)
    cfg_i = dataclasses.replace(
        cfg,
        base=base,
        policy=pol,
        timeout=scenario["timeout"],
        forecast_horizon=scenario.get("forecast_horizon", cfg.forecast_horizon),
    )
    state, n = engine.simulate(plat_i, wl, cfg_i, return_compiles=True)
    return metrics_from_state(state, plat_i), n


def _row(sc: dict, replication: int, m) -> dict:
    """One rows-table entry for grid point ``sc`` (the declarative dict,
    platform still a *name*) — shared by the blocking and streaming paths
    so their rows are identical by construction."""
    row = {
        "scheduler": sc["scheduler"],
        "timeout": sc["timeout"],
    }
    if "forecast" in sc:
        row["forecast"] = sc["forecast"]
    if "platform" in sc:
        row["platform"] = sc["platform"]
    row["replication"] = replication
    row.update(m.row())
    return row


def _warn_capped(rows) -> None:
    capped = [(r["scheduler"], r["timeout"]) for r in rows if r.get("truncated")]
    if capped:
        warnings.warn(
            f"experiment grid point(s) {capped} hit the batch cap before "
            "completing — their rows describe PARTIAL simulations "
            "('truncated' column). Raise max_batches to run to completion.",
            RuntimeWarning,
            stacklevel=3,
        )


def _resolve_run(experiment: Experiment, platform, workload):
    """Shared spec resolution for the blocking and streaming paths:
    validate the injection rules, resolve platform + engine config, and
    lower the declarative grid to traced sweep scenarios. Returns
    ``(plat, cfg, grid, scenarios)`` with ``grid`` keeping the
    platform-axis *names* for the rows table."""
    if workload is not None and experiment.replications > 1:
        raise ValueError(
            "cannot inject a workload into a run with replications > 1: "
            "replications >= 1 regenerate from the spec's workload entry, "
            "which need not match the injected object"
        )
    if experiment.out and (platform is not None or workload is not None):
        raise ValueError(
            "cannot combine injected platform/workload objects with "
            "experiment.out: metrics.json records the spec as the "
            "reproduction recipe, which would not describe what actually "
            "ran; write outputs yourself or put the platform/workload in "
            "the spec"
        )
    plat = platform if platform is not None else resolve_platform(experiment.platform)
    cfg = _engine_config_with_rl(experiment, plat)
    # swap platform-axis *names* for resolved PlatformSpecs (traced sweep
    # scenarios); the declarative grid keeps the names for the rows table
    grid = experiment.grid()
    axis = {name: resolve_platform(spec) for name, spec in experiment.platforms}
    scenarios = []
    for sc in grid:
        sc = dict(sc)
        if "platform" in sc:
            sc["platform"] = axis[sc["platform"]]
        if "forecast" in sc:
            # the declarative forecast axis lowers to the traced
            # EngineConst.forecast_horizon operand (§Forecast) — the raw
            # field-override branch of engine.sweep's scenario mapping
            sc["forecast_horizon"] = sc.pop("forecast")
        scenarios.append(sc)
    return plat, cfg, grid, scenarios


class StreamingRun:
    """Iterator of completed row-chunks from ``run(..., stream=True)``.

    Each ``next()`` blocks only until the *oldest* in-flight chunk's device
    work lands on the host, then yields that chunk's rows (a tuple of row
    dicts, grid order); the next chunk was already dispatched, so device
    compute overlaps the host-side consumption of earlier chunks. After
    exhaustion ``result`` holds the final :class:`ExperimentResult` —
    identical (and, via ``experiment.out``, byte-identical on disk) to what
    the blocking path returns.
    """

    def __init__(self, gen: Iterator[Tuple[dict, ...]]):
        self._gen = gen
        self.result: Optional[ExperimentResult] = None

    def __iter__(self) -> "StreamingRun":
        return self

    def __next__(self) -> Tuple[dict, ...]:
        return next(self._gen)


def run(
    experiment: Experiment,
    platform=None,
    workload=None,
    *,
    devices: Optional[Any] = None,
    stream: bool = False,
    chunk_scenarios: Optional[int] = None,
) -> ExperimentResult:
    """Run the experiment grid; one compiled program for everything.

    ``platform`` / ``workload`` optionally inject pre-resolved objects
    (benchmarks construct platforms programmatically); the spec remains the
    declarative record. With both injected and ``replications == 1`` the
    spec's workload/platform entries are never resolved. A workload can only
    be injected into a single-replication run: replications r >= 1 would be
    resolved from the spec, silently mixing two different studies.

    ``devices`` shards each sweep launch's scenario axis across local
    devices (``engine.sweep``'s contract: None/int/"all", bit-exact
    regardless; the single-point fast path runs one simulation and is
    never sharded). ``stream=True`` returns a :class:`StreamingRun`
    instead of blocking on the whole grid; ``chunk_scenarios`` bounds the
    scenarios per launch (default: the whole grid per replication).
    """
    if stream:
        return _run_stream(
            experiment,
            platform,
            workload,
            devices=devices,
            chunk_scenarios=chunk_scenarios,
        )
    if chunk_scenarios is not None:
        raise ValueError(
            "chunk_scenarios only applies to stream=True: the blocking "
            "path runs the whole grid as one launch (its one-compile / "
            "one-dispatch shape is the point)"
        )
    plat, cfg, grid, scenarios = _resolve_run(experiment, platform, workload)

    rows = []
    n_compiles: Optional[int] = None
    t0 = time.perf_counter()
    for r in range(experiment.replications):
        # an injected workload implies replications == 1 (guarded above)
        wl = (
            workload
            if workload is not None
            else resolve_workload(experiment.workload, replication=r)
        )
        with warnings.catch_warnings():
            # the engine layers warn per call; run() emits ONE aggregated
            # warning over the rows below, labelled with the grid points
            warnings.filterwarnings(
                "ignore", message=".*batch cap.*", category=RuntimeWarning
            )
            if len(scenarios) == 1:
                # single-point grid: the statically-specialized fast path
                # (one cached compile per config, dead rules DCE'd) instead
                # of the traced-superset sweep program — bit-exact either way
                metrics, n = _run_single(plat, wl, scenarios[0], cfg)
                batch_metrics = (metrics,)
            else:
                batch = engine.sweep(plat, wl, scenarios, cfg, devices=devices)
                batch_metrics, n = batch.metrics, batch.n_compiles
        if n is not None:
            n_compiles = max(n_compiles or 0, n)
        for sc, m in zip(grid, batch_metrics):
            rows.append(_row(sc, r, m))
    wall = time.perf_counter() - t0
    _warn_capped(rows)

    result = ExperimentResult(
        experiment=experiment,
        rows=tuple(rows),
        n_compiles=n_compiles,
        wall_s=wall,
    )
    if experiment.out:
        write_outputs(result, experiment.out)
    return result


# in-flight launches per StreamingRun: chunk k+1 is dispatched before chunk
# k's transfer drains (device compute overlaps host consumption); deeper
# pipelines buy nothing on one host and hold more device memory live
_STREAM_DEPTH = 2


def _run_stream(
    experiment: Experiment,
    platform,
    workload,
    *,
    devices: Optional[Any],
    chunk_scenarios: Optional[int],
) -> StreamingRun:
    """``run(..., stream=True)``: the same grid as launches of at most
    ``chunk_scenarios`` scenarios through ``engine.sweep_async``, yielded
    chunk-by-chunk as each lands. Rows, aggregated warning, final
    ExperimentResult, and (when ``experiment.out`` is set) the final
    ``metrics.json``/``rows.csv`` bytes are identical to the blocking path
    — the outputs are additionally REWRITTEN with rows-so-far after every
    chunk, so a crashed or abandoned stream leaves a valid prefix on disk.
    """
    plat, cfg, grid, scenarios = _resolve_run(experiment, platform, workload)
    chunk = chunk_scenarios if chunk_scenarios is not None else len(scenarios)
    if chunk < 1:
        raise ValueError(f"chunk_scenarios must be >= 1, got {chunk_scenarios!r}")
    single = len(scenarios) == 1

    holder = StreamingRun(iter(()))

    def gen():
        rows = []
        n_compiles: Optional[int] = None
        t0 = time.perf_counter()
        # (grid slice, replication, kind, payload) in dispatch order; rows
        # drain oldest-first so the table order matches the blocking path
        pending: deque = deque()

        def drain() -> Tuple[dict, ...]:
            nonlocal n_compiles
            grid_sl, r, kind, payload = pending.popleft()
            with warnings.catch_warnings():
                # per-launch truncation warnings surface at result() time;
                # aggregate them into the one labelled warning at the end
                warnings.filterwarnings(
                    "ignore", message=".*batch cap.*", category=RuntimeWarning
                )
                if kind == "single":
                    # single-point grid: the same statically-specialized
                    # path the blocking run takes (bit-exact rows); it
                    # computes synchronously here, at drain time
                    m, n = _run_single(plat, payload, scenarios[0], cfg)
                    batch_metrics = (m,)
                else:
                    batch = payload.result()
                    batch_metrics, n = batch.metrics, batch.n_compiles
            if n is not None:
                n_compiles = max(n_compiles or 0, n)
            chunk_rows = tuple(
                _row(sc, r, m) for sc, m in zip(grid_sl, batch_metrics)
            )
            rows.extend(chunk_rows)
            if experiment.out:
                # incremental rewrite with rows-so-far: always a valid
                # prefix; the last rewrite (all rows, n_compiles settled)
                # is byte-identical to the blocking path's single write
                write_outputs(
                    ExperimentResult(
                        experiment=experiment,
                        rows=tuple(rows),
                        n_compiles=n_compiles,
                        wall_s=time.perf_counter() - t0,
                    ),
                    experiment.out,
                )
            return chunk_rows

        for r in range(experiment.replications):
            # an injected workload implies replications == 1 (guarded in
            # _resolve_run)
            wl = (
                workload
                if workload is not None
                else resolve_workload(experiment.workload, replication=r)
            )
            if single:
                pending.append((grid, r, "single", wl))
                while len(pending) > _STREAM_DEPTH:
                    yield drain()
                continue
            for lo in range(0, len(scenarios), chunk):
                handle = engine.sweep_async(
                    plat, wl, scenarios[lo : lo + chunk], cfg, devices=devices
                )
                pending.append((grid[lo : lo + chunk], r, "sweep", handle))
                while len(pending) > _STREAM_DEPTH:
                    yield drain()
        while pending:
            yield drain()

        wall = time.perf_counter() - t0
        _warn_capped(rows)
        result = ExperimentResult(
            experiment=experiment,
            rows=tuple(rows),
            n_compiles=n_compiles,
            wall_s=wall,
        )
        if experiment.out:
            write_outputs(result, experiment.out)
        holder.result = result

    holder._gen = gen()
    return holder


def write_outputs(result: ExperimentResult, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "metrics.json"), "w") as f:
        json.dump(_metrics_payload(result), f, indent=2, sort_keys=True)
        f.write("\n")
    rows = result.rows
    lead = ["scheduler", "timeout", "forecast", "platform", "replication"]
    cols = sorted({k for r in rows for k in r}, key=lambda c: (
        lead.index(c) if c in lead else len(lead),
        c,
    ))
    with open(os.path.join(out_dir, "rows.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=cols)
        w.writeheader()
        w.writerows(rows)


def run_file(path: str) -> ExperimentResult:
    """CLI entry: load a spec file and run it (``launch/sim.py --experiment``)."""
    return run(Experiment.load(path))
