"""The frozen :class:`Experiment` spec and its JSON round-trip.

The spec is deliberately *declarative*: every field is a JSON value (or a
tuple of them), so ``to_json``/``from_json`` round-trip losslessly and a
spec file fully reproduces a study (seeded generators, pinned grids). The
workload/platform resolvers below are the single spelling shared by the
experiment runner and ``launch/sim.py``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Mapping, Optional, Tuple, Union

from repro.workloads.generator import PRESETS, GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec, load_platform
from repro.workloads.workload import Workload, load_workload


def check_unknown_keys(keys, known, where: str) -> None:
    """Reject unknown config keys loudly (with a did-you-mean hint) instead
    of silently ignoring typos. Shared by the experiment spec and the
    ``launch/sim.py`` single-run config."""
    unknown = sorted(set(keys) - set(known))
    if not unknown:
        return
    import difflib

    hints = []
    for k in unknown:
        close = difflib.get_close_matches(str(k), sorted(known), n=1)
        hints.append(
            f"{k!r}" + (f" (did you mean {close[0]!r}?)" if close else "")
        )
    raise ValueError(
        f"unknown {where} key(s): {', '.join(hints)}; "
        f"known keys: {', '.join(sorted(known))}"
    )


def check_workload_keys(spec: Mapping) -> None:
    """Fail fast (with a did-you-mean hint) on typo'd generator-override
    keys in a mapping workload spec — otherwise they surface as an opaque
    ``dataclasses.replace`` TypeError at run() time."""
    known = {f.name for f in dataclasses.fields(GeneratorConfig)} | {"preset"}
    check_unknown_keys(spec, known, "workload spec")


_KNOWN_SWF_KEYS = {
    "swf", "nb_nodes", "procs_per_node", "oversize", "max_jobs", "rebase",
}


def resolve_workload(spec, replication: int = 0) -> Workload:
    """Workload from a declarative spec.

    * ``"preset:<name>"`` — a seeded generator preset,
    * ``{"preset": <name>, ...GeneratorConfig overrides}`` — preset with
      overrides (e.g. ``n_jobs``),
    * ``{...GeneratorConfig fields}`` — a full generator config,
    * ``"swf:<path>"`` — SWF trace replay with the default adaptation
      (``traces.replay_workload``: platform sized from the trace header,
      submit times rebased to 0),
    * ``{"swf": <path>, ...replay_workload kwargs}`` — replay with
      explicit ``nb_nodes``/``procs_per_node``/``oversize``/``max_jobs``/
      ``rebase``,
    * ``"profiles"`` — the model-training job-profile workload,
    * a path to a workload JSON file, or an in-memory :class:`Workload`.

    ``replication`` offsets the generator seed (replication r uses
    ``seed + r``); file-backed, trace-replay, and in-memory workloads
    reject r > 0 — there is nothing to vary.
    """
    gcfg = None
    if isinstance(spec, str) and spec.startswith("preset:"):
        gcfg = PRESETS[spec.split(":", 1)[1]]
    elif isinstance(spec, str) and spec.startswith("swf:"):
        if replication:
            raise ValueError(
                f"workload spec {spec!r} is a trace replay; replications "
                "require a preset/generator spec (the seed is the "
                "replicate axis)"
            )
        from repro.workloads.traces import replay_workload

        return replay_workload(spec.split(":", 1)[1])
    elif isinstance(spec, Mapping) and "swf" in spec:
        if replication:
            raise ValueError(
                f"workload spec {spec!r} is a trace replay; replications "
                "require a preset/generator spec (the seed is the "
                "replicate axis)"
            )
        check_unknown_keys(spec, _KNOWN_SWF_KEYS, "swf workload spec")
        from repro.workloads.traces import replay_workload

        kw = dict(spec)
        return replay_workload(kw.pop("swf"), **kw)
    elif isinstance(spec, Mapping):
        check_workload_keys(spec)
        over = dict(spec)
        base = PRESETS[over.pop("preset")] if "preset" in over else GeneratorConfig()
        gcfg = dataclasses.replace(base, **over)
    if gcfg is not None:
        if replication:
            gcfg = dataclasses.replace(gcfg, seed=gcfg.seed + replication)
        return generate_workload(gcfg)
    if replication:
        raise ValueError(
            f"workload spec {spec!r} is not seeded-generated; replications "
            "require a preset/generator spec (the seed is the replicate axis)"
        )
    if isinstance(spec, Workload):
        return spec
    if spec == "profiles":
        from repro.configs.job_profiles import profile_workload

        return profile_workload()
    return load_workload(spec)


def resolve_platform(spec) -> PlatformSpec:
    """Platform from a declarative spec: an int node count, a platform JSON
    path or parsed dict (homogeneous / node_groups / per-node schemas), or
    an in-memory :class:`PlatformSpec`."""
    if isinstance(spec, PlatformSpec):
        return spec
    if isinstance(spec, int):
        return PlatformSpec(nb_nodes=spec)
    return load_platform(spec)


_KNOWN_RL_KEYS = {"checkpoint", "decision_interval"}


@dataclasses.dataclass(frozen=True)
class Experiment:
    """A declarative, reproducible grid study (JSON-round-trippable).

    The grid is the cross product ``schedulers x timeouts [x forecasts]
    [x platforms]``, evaluated as ONE compiled program per replication
    (``engine.sweep`` over the traced policy axis — platform tables and
    forecast horizons are traced operands too, so those axes vmap like
    every other). Scheduler labels come from
    ``policy.from_label``; a timeout of ``None`` means "never switch off".

    ``platforms`` is an optional *named* platform axis: a mapping
    ``{name: resolve_platform spec}`` (or a sequence of ``(name, spec)``
    pairs). When set, every grid point additionally carries a platform name
    and the base ``platform`` field is only the sweep's shape anchor —
    every axis entry must share its node/group counts and DVFS mode-table
    width. Rows gain a ``platform`` column.

    ``rl`` attaches a checkpointed controller to RL scheduler labels:
    ``{"checkpoint": <dir saved by training.checkpoint.save_policy>,
    "decision_interval": <s>}`` — the same block ``launch/sim.py`` takes.
    The controller is static trace structure shared by the whole grid, so
    all RL labels in one experiment must name the same policy stack;
    non-RL rows run with rule 8 traced off, unaffected.
    """

    name: str
    workload: Union[str, dict]  # resolve_workload spec
    platform: Union[str, int, dict]  # resolve_platform spec
    schedulers: Tuple[str, ...] = ("EASY PSUS",)
    timeouts: Tuple[Optional[int], ...] = (None,)
    # optional forecast-horizon axis (core/SEMANTICS.md §Forecast): seconds
    # of look-ahead for rule 10's EWMA predictor. Horizons are *traced*
    # EngineConst operands, so the whole horizon sweep rides the same ONE
    # compiled program as the scheduler/timeout axes. (None,) keeps the
    # grid forecast-free; entries only bite on ``+Forecast`` labels — on
    # any other stack the rule is flag-gated off regardless of horizon.
    forecasts: Tuple[Optional[int], ...] = (None,)
    forecast_alpha: float = 0.25  # shared EWMA smoothing weight in [0, 1]
    platforms: Tuple = ()  # optional named platform axis ((name, spec), ...)
    rl: Optional[dict] = None  # {"checkpoint": dir, "decision_interval": s}
    node_order: str = "id"  # "id" | "cheap" | "idle-watts" | "pack"
    # "any" | "partition" (core/SEMANTICS.md §Partition-aware allocation):
    # "partition" forbids cross-group allocations — a job takes the
    # earliest-completing single group that fits it, or fails to start
    allocation: str = "any"
    terminate_overrun: bool = False
    window: int = 32  # scheduler scan window (static)
    # static engine-structure knobs (core/SEMANTICS.md §Group-indexed
    # tables, §Hot loop) — shared by the whole grid like node_order/window
    grouped_tables: bool = False
    merge_bursts: bool = False
    replications: int = 1  # generator-seed replicates (seed, seed+1, ...)
    out: Optional[str] = None  # output dir for metrics.json / rows.csv

    def __post_init__(self):
        # normalize JSON lists to tuples so specs hash and compare stably
        object.__setattr__(self, "schedulers", tuple(self.schedulers))
        object.__setattr__(self, "timeouts", tuple(self.timeouts))
        object.__setattr__(self, "forecasts", tuple(self.forecasts))
        object.__setattr__(self, "platforms", self._norm_platforms())
        if not self.schedulers or not self.timeouts:
            raise ValueError("experiment grid needs >= 1 scheduler and timeout")
        if not self.forecasts:
            raise ValueError(
                "forecasts axis cannot be empty; use (None,) for no axis"
            )
        for fh in self.forecasts:
            if fh is not None and (not isinstance(fh, int) or fh < 0):
                raise ValueError(
                    f"forecast horizon entries must be None or ints >= 0, "
                    f"got {fh!r}"
                )
        if self.replications < 1:
            raise ValueError("replications must be >= 1")
        from repro.core.policy import from_label

        for label in self.schedulers:
            from_label(label)  # fail fast on unknown labels
        if isinstance(self.workload, Mapping):
            # fail fast on typo'd keys (swf replay specs have their own set)
            if "swf" in self.workload:
                check_unknown_keys(
                    self.workload, _KNOWN_SWF_KEYS, "swf workload spec"
                )
            else:
                check_workload_keys(self.workload)
        if self.rl is not None:
            check_unknown_keys(self.rl, _KNOWN_RL_KEYS, "experiment rl")

    def _norm_platforms(self) -> Tuple:
        """Normalize the platform axis to ((name, json-able spec), ...)."""
        from repro.workloads.platform import PlatformSpec

        entries = self.platforms
        if isinstance(entries, Mapping):
            entries = tuple(entries.items())
        out = []
        for e in entries:
            if isinstance(e, str) or not hasattr(e, "__len__") or len(e) != 2:
                raise ValueError(
                    f"platform-axis entry {e!r} is not a (name, spec) pair "
                    "(pass a mapping {name: spec} or a pair sequence)"
                )
            name, spec = e
            if isinstance(spec, PlatformSpec):
                spec = spec.to_json()  # keep the spec JSON-round-trippable
            out.append((str(name), spec))
        names = [n for n, _ in out]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate platform-axis names: {names}")
        return tuple(out)

    # ---- grid ----
    def grid(self):
        """The declarative grid points, in row order (scheduler-major, then
        timeout, then forecast horizon, then platform-axis entry). The
        runner swaps each point's platform *name* for the resolved
        :class:`PlatformSpec` (and the ``forecast`` key for its traced
        ``forecast_horizon`` operand) before handing the scenarios to
        ``engine.sweep``. A trivial ``(None,)`` forecasts axis adds no
        ``forecast`` key, so forecast-free grids keep their legacy row
        shape."""
        plats = [name for name, _ in self.platforms] or [None]
        return [
            {"scheduler": s, "timeout": t, **(
                {"forecast": fh} if fh is not None else {}
            ), **(
                {"platform": p} if p is not None else {}
            )}
            for s in self.schedulers
            for t in self.timeouts
            for fh in self.forecasts
            for p in plats
        ]

    def engine_config(self):
        """The shared static EngineConfig (every grid point is a traced
        scenario over it)."""
        from repro.core.types import EngineConfig

        return EngineConfig(
            node_order=self.node_order,
            allocation=self.allocation,
            terminate_overrun=self.terminate_overrun,
            window=self.window,
            grouped_tables=self.grouped_tables,
            merge_bursts=self.merge_bursts,
            forecast_alpha=self.forecast_alpha,
        )

    # ---- JSON round-trip ----
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "Experiment":
        obj = json.loads(text)
        if not isinstance(obj, Mapping):
            raise ValueError("experiment JSON must be an object")
        check_unknown_keys(
            obj, {f.name for f in dataclasses.fields(cls)}, "experiment"
        )
        return cls(**obj)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "Experiment":
        with open(path) as f:
            return cls.from_json(f.read())
