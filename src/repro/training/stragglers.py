"""Straggler detection: per-step wall-time watchdog with EWMA baseline.

On a synchronous SPMD program a single slow host gates every step (the
all-reduce waits). The watchdog keeps an exponentially-weighted baseline of
step time; a step slower than ``threshold x baseline`` raises a flag, and
``k`` consecutive flags fire the mitigation callback (checkpoint + evict +
elastic reshard in launch/train.py — see elastic.py).

In a real deployment each host also reports its *pre-barrier* compute time
via an all-gather of one scalar so the slow host is identifiable
(``attribute()``); the single-process container exercises the same logic
with injected timings (tests/test_stragglers.py uses a fake clock).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np


@dataclasses.dataclass
class WatchdogConfig:
    ewma_alpha: float = 0.1
    threshold: float = 2.0  # step is "slow" above threshold x baseline
    patience: int = 3  # consecutive slow steps before firing
    warmup_steps: int = 5  # compile/first-touch steps excluded from baseline


class StepWatchdog:
    def __init__(
        self,
        config: WatchdogConfig = WatchdogConfig(),
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cfg = config
        self.on_straggler = on_straggler
        self.clock = clock
        self.baseline: Optional[float] = None
        self.step = 0
        self._slow_run = 0
        self._t0: Optional[float] = None
        self.history: List[float] = []
        self.fired = 0

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()

    def start(self) -> None:
        self._t0 = self.clock()

    def stop(self) -> float:
        """Record one step; returns its duration. Fires callback on patience."""
        assert self._t0 is not None, "start() not called"
        dt = self.clock() - self._t0
        self._t0 = None
        self.step += 1
        self.history.append(dt)
        if self.step <= self.cfg.warmup_steps:
            return dt
        if self.baseline is None:
            self.baseline = dt
            return dt
        slow = dt > self.cfg.threshold * self.baseline
        if slow:
            self._slow_run += 1
            if self._slow_run >= self.cfg.patience:
                self.fired += 1
                self._slow_run = 0
                if self.on_straggler is not None:
                    self.on_straggler(self.step, dt, self.baseline)
        else:
            self._slow_run = 0
            a = self.cfg.ewma_alpha
            self.baseline = (1 - a) * self.baseline + a * dt
        return dt


def attribute(per_host_compute_s: np.ndarray, threshold: float = 1.5):
    """Which hosts are stragglers, from the all-gathered pre-barrier times.

    Returns (indices, median): hosts slower than threshold x median.
    """
    med = float(np.median(per_host_compute_s))
    idx = np.nonzero(per_host_compute_s > threshold * med)[0]
    return idx.tolist(), med
