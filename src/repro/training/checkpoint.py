"""Sharded checkpointing with async writes and atomic publish.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json          # pytree structure, paths, dtypes, step
        <leaf-path>.npy        # one array file per leaf (host-gathered)
    <dir>/LATEST               # atomic pointer file, written last

Write protocol (crash-safe at every point):
  1. write everything into ``step_<n>.tmp-<pid>``
  2. ``os.rename`` the tmp dir to ``step_<n>``   (atomic on POSIX)
  3. rewrite ``LATEST`` via tmp-file + rename     (atomic)

A checkpoint is visible to ``restore_latest`` only after step 3, so a
killed writer can never publish a torn checkpoint — the restart test in
tests/test_checkpoint.py kills a write mid-flight and proves recovery from
the previous step.

``save_async`` runs steps 1-3 on a daemon thread: training hands off
host-side copies (``jax.device_get``) and continues; the next save (or
``wait()``) joins the previous thread. On a real multi-host cluster each
host writes only the shards it owns (``process_index`` prefix) — the
single-process container exercises the same code path with one writer.

Restore is lazy-sharded: leaves are loaded host-side and ``device_put``
against the target shardings (pass ``shardings=`` to place directly onto
the production mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_SEP = "."

# numpy cannot round-trip the ML dtypes through .npy; store them as a
# same-width integer view and recover via the manifest's dtype string
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    for name, (dt, view) in _EXOTIC.items():
        if arr.dtype == dt:
            return arr.view(view)
    return arr


def _from_savable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _EXOTIC:
        return arr.view(_EXOTIC[dtype_str][0])
    return arr


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SEP.join(parts) or "ROOT"


def _flatten_with_paths(tree: PyTree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), x) for p, x in leaves], treedef


class Checkpointer:
    """Async checkpoint writer with atomic publish + bounded retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree, meta: Optional[Dict] = None) -> None:
        """Blocking save (used by save_async's worker)."""
        named, _ = _flatten_with_paths(tree)
        arrays = [(name, np.asarray(jax.device_get(x))) for name, x in named]
        self._write(step, arrays, meta or {})

    def save_async(self, step: int, tree: PyTree, meta: Optional[Dict] = None) -> None:
        """Device-get on the caller, file I/O on a daemon thread."""
        self.wait()  # one outstanding write at a time
        named, _ = _flatten_with_paths(tree)
        arrays = [(name, np.asarray(jax.device_get(x))) for name, x in named]
        m = dict(meta or {})

        def worker():
            try:
                self._write(step, arrays, m)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, arrays, meta: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp-{os.getpid()}-{threading.get_ident()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "meta": meta, "leaves": []}
        for name, arr in arrays:
            fname = name.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fname), _to_savable(arr))
            manifest["leaves"].append(
                {"path": name, "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish of the step dir
        # atomic LATEST pointer
        ptr_tmp = os.path.join(self.dir, f".LATEST.tmp-{os.getpid()}")
        with open(ptr_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.rename(ptr_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.dir)
            if d.startswith("step_") and ".tmp" not in d
        )
        for d in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "manifest.json")):
            return None
        return int(name.split("_")[1])

    def restore(
        self,
        like: PyTree,
        step: Optional[int] = None,
        shardings: Optional[PyTree] = None,
    ) -> Tuple[int, PyTree]:
        """Restore into the structure of ``like`` (shapes/dtypes verified).

        ``shardings``: optional pytree of NamedSharding — leaves are
        device_put directly to their production placement.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        named, treedef = _flatten_with_paths(like)
        sh_leaves = (
            [s for _, s in _flatten_with_paths(shardings)[0]]
            if shardings is not None
            else [None] * len(named)
        )
        out = []
        for (name, proto), sh in zip(named, sh_leaves):
            e = by_path[name]
            arr = _from_savable(np.load(os.path.join(d, e["file"])), e["dtype"])
            want = tuple(getattr(proto, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"{name}: checkpoint shape {arr.shape} != {want}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=getattr(proto, "dtype", arr.dtype)))
        return step, jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out
        )


def restore_or_init(
    ckpt: Checkpointer,
    init_fn: Callable[[], PyTree],
    shardings: Optional[PyTree] = None,
) -> Tuple[int, PyTree]:
    """Restart-from-latest: the launcher's crash-recovery entry point."""
    step = ckpt.latest_step()
    if step is None:
        return 0, init_fn()
    like = jax.eval_shape(init_fn)
    return ckpt.restore(like, step=step, shardings=shardings)


# --------------------------------------------------------------------------
# Versioned RL policy checkpoints
#
# Raw param trees used to be saved with no header, so a checkpoint trained
# before the observation/action space changed (e.g. the pre-heterogeneity
# obs-16 era, or global-action vs group-action controllers) failed deep in
# restore with a shape error. save_policy/load_policy stamp a typed header
# and turn every mismatch into an actionable migration message.
# --------------------------------------------------------------------------

# version 1: implicit/headerless (pre-hetero, obs 16, global actions only).
# version 2: explicit header with obs/action-space fields (hetero features,
#            group-targeted actions).
POLICY_CKPT_VERSION = 2
_POLICY_KIND = "rl-policy"


def save_policy(
    directory: str,
    params: PyTree,
    *,
    obs_size: int,
    n_actions: int,
    feature: str,
    action: str,
    n_levels: int,
    hidden: Tuple[int, ...] = (128, 128),
    feature_window: int = 8,
    grouped: bool = False,
    n_groups: int = 1,
    dvfs: bool = False,
    step: int = 0,
) -> None:
    """Save an RL policy with the versioned header ``load_policy`` checks.

    ``dvfs``: the policy was trained commanding DVFS modes
    (``RLController(dvfs=True)``; for mode actions ``n_levels`` is the
    platform's mode-table width).
    """
    meta = {
        "kind": _POLICY_KIND,
        "version": POLICY_CKPT_VERSION,
        "obs_size": int(obs_size),
        "n_actions": int(n_actions),
        "feature": feature,
        "action": action,
        "n_levels": int(n_levels),
        "hidden": [int(h) for h in hidden],
        "feature_window": int(feature_window),
        "grouped": bool(grouped),
        "n_groups": int(n_groups),
        "dvfs": bool(dvfs),
    }
    Checkpointer(directory).save(step, params, meta)


def _policy_meta(directory: str) -> Tuple[int, Dict]:
    ck = Checkpointer(directory)
    step = ck.latest_step()
    if step is None:
        raise FileNotFoundError(f"no policy checkpoint in {directory}")
    with open(
        os.path.join(directory, f"step_{step:08d}", "manifest.json")
    ) as f:
        manifest = json.load(f)
    return step, manifest.get("meta") or {}


def load_policy(
    directory: str,
    expect_obs_size: Optional[int] = None,
    expect_n_actions: Optional[int] = None,
) -> Tuple[PyTree, Dict]:
    """Load a policy saved by :func:`save_policy`, validating its header.

    Raises ``ValueError`` with a migration message for headerless (pre-hetero
    obs-16 era) checkpoints and for observation/action-space mismatches,
    instead of an opaque shape error mid-restore.
    """
    from repro.core.rl.networks import policy_init

    step, meta = _policy_meta(directory)
    if meta.get("kind") != _POLICY_KIND or "version" not in meta:
        raise ValueError(
            f"checkpoint in {directory!r} has no RL-policy header: it "
            "predates checkpoint versioning (pre-heterogeneity, obs-16, "
            "global-action era) and its parameter shapes do not match the "
            "current observation/action spaces. Retrain and re-save with "
            "training.checkpoint.save_policy, or restore the raw tree "
            "manually via Checkpointer.restore if you know its layout."
        )
    if meta["version"] != POLICY_CKPT_VERSION:
        raise ValueError(
            f"RL policy checkpoint version {meta['version']} != supported "
            f"{POLICY_CKPT_VERSION}; retrain or migrate the checkpoint "
            f"({directory!r})"
        )
    if expect_obs_size is not None and meta["obs_size"] != expect_obs_size:
        raise ValueError(
            f"RL policy checkpoint {directory!r} was trained with "
            f"obs_size={meta['obs_size']} (feature {meta['feature']!r}) but "
            f"this run expects obs_size={expect_obs_size} — the observation "
            "space changed (e.g. pre-hetero 16 -> 20); retrain the policy "
            "or run with the checkpoint's feature configuration"
        )
    if expect_n_actions is not None and meta["n_actions"] != expect_n_actions:
        raise ValueError(
            f"RL policy checkpoint {directory!r} has "
            f"n_actions={meta['n_actions']} (action {meta['action']!r}, "
            f"grouped={meta['grouped']}) but this run expects "
            f"{expect_n_actions} — action spaces are incompatible; retrain "
            "or select the checkpoint's action space"
        )
    like = jax.eval_shape(
        lambda: policy_init(
            jax.random.PRNGKey(0),
            meta["obs_size"],
            meta["n_actions"],
            tuple(meta["hidden"]),
        )
    )
    _, params = Checkpointer(directory).restore(like, step=step)
    return params, meta
