"""Distributed training runtime: optimizers, train step, checkpointing,
data pipeline, elasticity, gradient compression."""
from repro.training.optimizer import (
    OptState,
    adamw,
    adafactor,
    sgd,
    clip_by_global_norm,
    cosine_schedule,
)

__all__ = [
    "OptState",
    "adamw",
    "adafactor",
    "sgd",
    "clip_by_global_norm",
    "cosine_schedule",
]
