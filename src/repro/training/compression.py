"""Gradient compression for cross-pod reduces: int8 quantization and top-k
sparsification with error feedback.

At 2+ pods the gradient all-reduce crosses the (slow) inter-pod links; a
314B-model's bf16 gradients are ~630 GB per step of wire traffic. Two
standard mitigations, both pure pytree transforms:

* ``int8``  — per-tensor symmetric quantization. The wire carries int8 +
  one f32 scale (4x less than bf16); here the quant-dequant roundtrip is
  applied *before* the (GSPMD-inserted) all-reduce so the numerics match a
  production int8-wire implementation whose reduce is performed on the
  dequantized values.
* ``topk``  — keep the largest-|g| fraction per tensor; the wire carries
  (indices, values). Biased on its own, so pair it with ``ErrorFeedback``
  (Karimireddy et al., 2019): the residual of what was not sent is added
  back to the next step's gradient — SGD convergence is then preserved.

``COMPRESSORS`` maps ``TrainStepConfig.compression`` names to stateless
transforms; ``ErrorFeedback`` is the stateful wrapper used by the launcher
when ``--compression topk`` is combined with ``--error-feedback``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# int8 per-tensor symmetric quantization
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_compress(grads: PyTree) -> PyTree:
    """Quant-dequant roundtrip (wire-numerics simulation, 4x compression)."""

    def leaf(g):
        if g.ndim < 1 or g.size < 1024:  # tiny tensors: not worth the scale
            return g
        q, s = quantize_int8(g)
        return dequantize_int8(q, s, g.dtype)

    return jax.tree_util.tree_map(leaf, grads)


# ---------------------------------------------------------------------------
# top-k magnitude sparsification
# ---------------------------------------------------------------------------

def topk_compress(grads: PyTree, fraction: float = 0.05) -> PyTree:
    """Keep the top-``fraction`` |g| entries per tensor (rest zeroed)."""

    def leaf(g):
        if g.ndim < 1 or g.size < 1024:
            return g
        k = max(1, int(g.size * fraction))
        flat = jnp.abs(g.reshape(-1).astype(jnp.float32))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = jnp.abs(g.astype(jnp.float32)) >= thresh
        return jnp.where(mask, g, jnp.zeros_like(g))

    return jax.tree_util.tree_map(leaf, grads)


COMPRESSORS: Dict[str, Callable[[PyTree], PyTree]] = {
    "int8": int8_compress,
    "topk": topk_compress,
}


# ---------------------------------------------------------------------------
# error feedback (stateful wrapper)
# ---------------------------------------------------------------------------

class ErrorFeedbackState(NamedTuple):
    residual: PyTree  # f32, same structure as grads


def error_feedback_init(params: PyTree) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def error_feedback_apply(
    state: ErrorFeedbackState,
    grads: PyTree,
    compressor: Callable[[PyTree], PyTree],
) -> Tuple[PyTree, ErrorFeedbackState]:
    """compressed(g + residual); residual' = (g + residual) - compressed."""
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, state.residual
    )
    sent = compressor(corrected)
    residual = jax.tree_util.tree_map(
        lambda c, s: c - s.astype(jnp.float32), corrected, sent
    )
    sent = jax.tree_util.tree_map(
        lambda s, g: s.astype(g.dtype), sent, grads
    )
    return sent, ErrorFeedbackState(residual)
