"""Elastic resharding: continue training when the data axis shrinks/grows.

A node failure at 1000+-node scale is a when, not an if. The recovery path
is: detect (stragglers.py watchdog or a dead collective), rebuild the mesh
over the surviving hosts, reshard the live state, resume. Because all state
is a pytree of jax.Arrays with NamedShardings, resharding is a single
``device_put`` against the new mesh — XLA moves only the shards that
actually change owner.

Semantics preserved across a resize:
  * params/opt state: value-identical (verified in tests at 8->4 and 4->8)
  * global batch: constant — per-device batch rescales, and if the new
    data-parallel degree no longer divides the global batch, gradient
    accumulation absorbs the remainder (``plan_batch``)
  * RL envs (leading env axis): envs are redistributed, surplus envs
    beyond an even split are dropped deterministically from the tail
    (they are i.i.d. rollout streams; dropping preserves on-policy-ness)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    global_batch: int
    per_device: int
    accum_steps: int
    dp_degree: int


def plan_batch(global_batch: int, dp_degree: int, max_per_device: int) -> BatchPlan:
    """Keep global batch fixed as DP degree changes; spill into accumulation."""
    per_replica = global_batch // dp_degree
    if global_batch % dp_degree != 0:
        raise ValueError(
            f"global_batch {global_batch} not divisible by dp={dp_degree}; "
            "choose a batch with enough factors for elastic range"
        )
    accum = 1
    while per_replica // accum > max_per_device or per_replica % accum != 0:
        accum += 1
        if accum > per_replica:
            raise ValueError("cannot satisfy max_per_device")
    return BatchPlan(global_batch, per_replica // accum, accum, dp_degree)


def reshard(
    tree: PyTree,
    new_mesh: Mesh,
    sharding_fn: Callable[[Mesh, Any], PyTree],
) -> PyTree:
    """Move a live pytree onto a new mesh. ``sharding_fn(mesh, shapes)``
    rebuilds the NamedSharding tree (e.g. functools.partial wrapping
    models.sharding.params_shardings)."""
    shapes = jax.eval_shape(lambda t: t, tree)
    new_sh = sharding_fn(new_mesh, shapes)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, new_sh
    )


def shrink_env_axis(tree: PyTree, new_count: int) -> PyTree:
    """Drop surplus envs from the tail of the leading axis (deterministic)."""
    return jax.tree_util.tree_map(lambda x: x[:new_count], tree)


def grow_env_axis(tree: PyTree, new_count: int) -> PyTree:
    """Tile existing envs to fill new slots (fresh resets happen next step)."""

    def leaf(x):
        reps = -(-new_count // x.shape[0])  # ceil
        return jax.numpy.tile(x, (reps,) + (1,) * (x.ndim - 1))[:new_count]

    return jax.tree_util.tree_map(leaf, tree)


def surviving_mesh(
    n_devices: int, model_parallel: int, axis_names: Tuple[str, str] = ("data", "model")
) -> Mesh:
    """Largest (data, model) mesh on the surviving device set."""
    usable = (n_devices // model_parallel) * model_parallel
    devs = np.asarray(jax.devices()[:usable]).reshape(-1, model_parallel)
    return Mesh(devs, axis_names)
