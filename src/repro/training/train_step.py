"""Train / serve step builders: loss + grad + optimizer update with optional
microbatch gradient accumulation (``lax.scan``), remat policy inherited from
the model's scan-over-layers blocks.

Gradient accumulation is also the compute/comm overlap mechanism: with the
update outside the microbatch scan, XLA overlaps each microbatch's gradient
reduce-scatter with the next microbatch's compute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.training.optimizer import (
    Optimizer,
    OptState,
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    accum_steps: int = 1
    max_grad_norm: float = 1.0
    lr: float = 3e-4
    compression: Optional[str] = None  # None | "int8" | "topk" (see compression.py)
    # mesh axes carrying the batch dim; with accumulation the
    # (B,) -> (accum, B/accum) reshape loses the batch sharding unless it is
    # re-pinned, and GSPMD then runs every microbatch over the FULL local
    # batch (4-8x redundant FLOPs — found via the roofline dry-run, see
    # EXPERIMENTS.md §Perf iteration 1)
    batch_axes: Optional[Tuple[str, ...]] = None
    # PartitionSpec pytree matching params: pins the f32 gradient
    # accumulator to the parameter sharding so the cross-data-axis reduce
    # happens ONCE per step instead of per microbatch (qwen2-moe: the
    # accumulator was replicated -> per-microbatch expert-grad all-reduces;
    # EXPERIMENTS.md §Perf)
    grad_specs: Optional[Any] = None


def make_optimizer(name: str, lr) -> Optimizer:
    if name == "adafactor":
        return adafactor(lr=lr)
    if name == "adamw":
        return adamw(lr=lr, b2=0.95, weight_decay=0.1, moment_dtype=jnp.bfloat16)
    raise KeyError(name)


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    cfg: TrainStepConfig = TrainStepConfig(),
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With accum_steps > 1, the batch's leading axis is split into
    (accum, B/accum) microbatches scanned sequentially; gradients are
    averaged in f32.
    """
    compress = None
    if cfg.compression:
        from repro.training.compression import COMPRESSORS

        compress = COMPRESSORS[cfg.compression]

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(params, opt_state: OptState, batch):
        if cfg.accum_steps == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            a = cfg.accum_steps

            def split(x):
                y = x.reshape((a, x.shape[0] // a) + x.shape[1:])
                if cfg.batch_axes:
                    from jax.sharding import PartitionSpec as P

                    spec = P(None, cfg.batch_axes, *([None] * (y.ndim - 2)))
                    y = jax.lax.with_sharding_constraint(y, spec)
                return y

            micro = jax.tree_util.tree_map(split, batch)

            def pin_grads(tree):
                if cfg.grad_specs is None:
                    return tree
                return jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    tree,
                    cfg.grad_specs,
                )

            def body(carry, mb):
                gsum, lsum = carry
                loss, _, grads = grads_of(params, mb)
                gsum = pin_grads(
                    jax.tree_util.tree_map(
                        lambda acc, g: acc + g.astype(jnp.float32), gsum, grads
                    )
                )
                return (gsum, lsum + loss), None

            gzero = pin_grads(
                jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
            )
            (gsum, lsum), _ = jax.lax.scan(body, (gzero, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / a, gsum)
            loss = lsum / a
            metrics = {}
        if compress is not None:
            grads = compress(grads)
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        out_metrics.update({k: v for k, v in (metrics or {}).items()})
        return params, opt_state, out_metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}

    return eval_step
