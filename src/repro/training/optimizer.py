"""Optimizers as pure pytree transforms (no optax dependency).

API (optax-like, minimal):

    opt = adamw(lr=3e-4, weight_decay=0.1, moment_dtype=jnp.bfloat16)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

``moment_dtype=bfloat16`` halves optimizer HBM for the large assigned archs;
``adafactor`` factors the second moment (rank-1) for grok-1-class models where
even bf16 Adam moments are too expensive.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, Optional[PyTree]], Tuple[PyTree, OptState]]


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return _tree_map(lambda p, u: (p + u.astype(p.dtype)) if p is not None else None, params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return _tree_map(lambda x: x * scale.astype(x.dtype), tree), g


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup_steps, warm, cos)

    return fn


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# SGD (+momentum)
# ---------------------------------------------------------------------------

def sgd(lr=1e-2, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            m = _tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        else:
            m = None
        return OptState(jnp.zeros((), jnp.int32), m)

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            m = _tree_map(
                lambda mm, g: momentum * mm + g.astype(jnp.float32), state.inner, grads
            )
            upd = _tree_map(lambda mm: -lr_t * mm, m)
            return upd, OptState(step, m)
        upd = _tree_map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, OptState(step, None)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class _AdamMoments(NamedTuple):
    mu: PyTree
    nu: PyTree


def adamw(
    lr=3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    moment_dtype=jnp.float32,
    mask: Optional[Callable[[PyTree], PyTree]] = None,
) -> Optimizer:
    """AdamW with optional low-precision moments (bf16 halves optimizer HBM).

    ``mask(params)`` returns a pytree of bools selecting leaves that receive
    weight decay (default: all leaves with ndim >= 2 — norms/biases excluded).
    """

    def decay_mask(params):
        if mask is not None:
            return mask(params)
        return _tree_map(lambda p: p.ndim >= 2, params)

    def init(params):
        mu = _tree_map(lambda p: jnp.zeros_like(p, moment_dtype), params)
        nu = _tree_map(lambda p: jnp.zeros_like(p, moment_dtype), params)
        return OptState(jnp.zeros((), jnp.int32), _AdamMoments(mu, nu))

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        stepf = step.astype(jnp.float32)
        bc1 = 1 - b1**stepf
        bc2 = 1 - b2**stepf

        def upd_moments(mu, nu, g):
            g32 = g.astype(jnp.float32)
            mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
            nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
            return mu32, nu32

        mus, nus = [], []
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_mu = treedef.flatten_up_to(state.inner.mu)
        flat_nu = treedef.flatten_up_to(state.inner.nu)
        flat_p = treedef.flatten_up_to(params) if params is not None else [None] * len(flat_g)
        flat_mask = (
            treedef.flatten_up_to(decay_mask(params)) if params is not None else [False] * len(flat_g)
        )
        upds = []
        for g, mu, nu, p, dm in zip(flat_g, flat_mu, flat_nu, flat_p, flat_mask):
            mu32, nu32 = upd_moments(mu, nu, g)
            u = -lr_t * (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * jnp.where(dm, 1.0, 0.0) * p.astype(jnp.float32)
            upds.append(u)
            mus.append(mu32.astype(moment_dtype))
            nus.append(nu32.astype(moment_dtype))
        inner = _AdamMoments(
            treedef.unflatten(mus), treedef.unflatten(nus)
        )
        return treedef.unflatten(upds), OptState(step, inner)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; default for grok-1-314b-class models)
# ---------------------------------------------------------------------------

class _FactorState(NamedTuple):
    vr: PyTree  # row stats (or full v for <2D leaves)
    vc: PyTree  # col stats (or None-placeholders)


def adafactor(
    lr=1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    """Adafactor (Shazeer & Stern, 2018) without momentum.

    2D+ leaves with both trailing dims >= min_dim_size_to_factor store
    factored row/col second-moment stats: O(n+m) instead of O(nm) memory.
    """

    def factored(p):
        return p.ndim >= 2 and min(p.shape[-2:]) >= min_dim_size_to_factor

    def init(params):
        def vr_init(p):
            if factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, jnp.float32)

        def vc_init(p):
            if factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)  # unused placeholder

        return OptState(
            jnp.zeros((), jnp.int32),
            _FactorState(_tree_map(vr_init, params), _tree_map(vc_init, params)),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_vr = treedef.flatten_up_to(state.inner.vr)
        flat_vc = treedef.flatten_up_to(state.inner.vc)
        upds, vrs, vcs = [], [], []
        for g, vr, vc in zip(flat_g, flat_vr, flat_vc):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2 and vr.shape == g.shape[:-1]:
                vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps
                )
                cfac = jax.lax.rsqrt(vc + eps)
                u = g32 * rfac[..., None] * cfac[..., None, :]
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(vr + eps)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            upds.append(-lr_t * u)
            vrs.append(vr)
            vcs.append(vc)
        inner = _FactorState(treedef.unflatten(vrs), treedef.unflatten(vcs))
        return treedef.unflatten(upds), OptState(step, inner)

    return Optimizer(init, update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}
