"""GPipe-style pipeline parallelism over a mesh axis via shard_map +
collective_permute.

The model's scan-over-layers stack splits into ``n_stages`` contiguous
stages; each pipeline rank holds ONE stage's parameters (sharded over the
``pipe`` mesh axis) and microbatched activations flow rank->rank+1 with
``jax.lax.ppermute``. The schedule is the classic GPipe fill-drain loop of
``n_micro + n_stages - 1`` ticks; bubble fraction = (S-1)/(M+S-1), so
n_micro >= 4 x n_stages keeps it under ~20%.

This is OFF by default (DP over pods wins at 2 pods — the gradient
all-reduce overlaps with accumulation, while a 2-stage pipeline adds a
bubble and cross-pod activation traffic *per microbatch*; see EXPERIMENTS.md
§Perf for the measured trade). It exists so the same launcher scales to
meshes where the model axis alone cannot hold the weights — and it is
dry-run-verified on the (pod, data, model) production mesh in
tests/test_pipeline.py.

Activation shapes must be rank-invariant (same [mb, S, D] at every stage),
which holds for every assigned arch's homogeneous trunk.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

PyTree = Any


def pipeline_forward(
    stage_fn: Callable[[PyTree, jax.Array], jax.Array],
    stage_params: PyTree,  # leaves with leading axis == n_stages (sharded over pipe axis)
    x: jax.Array,  # [n_micro, mb, ...] microbatched input (replicated)
    *,
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run x through the stage pipeline. Returns [n_micro, mb, ...] outputs.

    Inside shard_map each rank sees stage_params[1, ...] (its own stage) and
    the full microbatch stream. Rank r processes microbatch m at tick
    t = m + r; activations hop via ppermute; outputs are collected on the
    last rank then broadcast (all ranks return identical outputs).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1

    def per_rank(params, xs):
        # params: [1, ...] this rank's stage; xs: [n_micro, mb, ...] (full)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        rank = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros_like(xs)  # collected outputs (last rank)
        carry = jnp.zeros(mb_shape, xs.dtype)  # activation entering this rank

        def tick(t, state):
            carry, buf = state
            # rank 0 ingests microbatch t; others use the permuted carry
            x_in = jnp.where(
                rank == 0,
                jax.lax.dynamic_index_in_dim(
                    xs, jnp.minimum(t, n_micro - 1), 0, keepdims=False
                ),
                carry,
            )
            my_m = t - rank  # microbatch index this rank works on at tick t
            active = jnp.logical_and(my_m >= 0, my_m < n_micro)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, x_in)
            # last rank collects finished microbatches
            buf = jnp.where(
                jnp.logical_and(rank == n_stages - 1, active),
                jax.lax.dynamic_update_index_in_dim(buf, y, jnp.maximum(my_m, 0), 0),
                buf,
            )
            # hop to the next rank (ring; the wrap-around value is ignored)
            carry = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return carry, buf

        _, buf = jax.lax.fori_loop(0, n_ticks, tick, (carry, buf))
        # broadcast results from the last rank to all ranks
        out = jax.lax.ppermute(
            buf, axis, [((n_stages - 1 + i) % n_stages, i) for i in range(n_stages)]
        )
        # ranks other than the one fed by last now hold garbage; an
        # all-gather-max settles it (outputs are identical where valid)
        out = jnp.where(rank == 0, out, jnp.zeros_like(out))
        out = jax.lax.psum(out, axis)
        return out

    spec_p = jax.tree_util.tree_map(
        lambda _: P(axis), stage_params
    )
    fn = shard_map(
        per_rank,
        mesh=mesh,
        in_specs=(spec_p, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)


def split_stages(stacked_params: PyTree, n_stages: int) -> PyTree:
    """[L, ...] layer-stacked params -> [n_stages, L/n_stages, ...]."""

    def leaf(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])

    return jax.tree_util.tree_map(leaf, stacked_params)


def make_stage_fn(
    block_apply: Callable[[PyTree, jax.Array], jax.Array],
) -> Callable[[PyTree, jax.Array], jax.Array]:
    """Wrap a single-layer apply into a scan over the stage's layer stack."""

    def stage_fn(stage_params, x):
        def body(xx, lp):
            return block_apply(lp, xx), None

        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    return stage_fn
