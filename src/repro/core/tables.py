"""Group-indexed platform tables (core/SEMANTICS.md §Group-indexed tables).

At CEA-Curie scale (11,200 nodes) the engine's dense per-node tables
(``power[N, 5]``, ``speed[N]``, ``t_on/t_off[N]``) make every event batch
pay O(N) — and the per-attempt allocation argsorts pay O(N log N) — even
though a real platform has only G ~ dozens of *distinct* node kinds.
:class:`GroupTables` lowers a :class:`~repro.workloads.platform.PlatformSpec`
to per-group arrays so the hot reductions scale with G instead:

- energy accrual becomes the contraction ``occ[G, 5] · power[G, 5]`` over
  the per-(group, state) occupancy histogram carried in ``SimState.occ``,
- allocation hoists its node order out of the per-attempt loop — one
  (often zero) argsort per scheduler pass instead of two per attempt
  (the order-hoisting argument is spelled out in
  ``engine._scheduler_pass``) — selecting nodes by a masked cumsum,
- the DVFS mode tables are *already* group-indexed in ``EngineConst``
  (``dvfs_speed/dvfs_watts[G, M]``); ``GroupTables`` completes the set.

The dense path stays in the engine verbatim as the bit-exact baseline;
``EngineConfig.grouped_tables`` (static, part of ``_static_trace_key``)
selects between them. Every ``GroupTables`` member is a *traced operand*
(platform sweeps vmap; only G itself is a shape).

Groups must be internally uniform for the lowering to be exact —
:func:`group_tables` verifies this on the host and refuses a platform
whose per-node tables vary within a group (possible via the per-node JSON
schema) rather than silently averaging.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ACTIVE, IDLE
from repro.workloads.platform import PlatformSpec

__all__ = ["GroupTables", "group_tables"]


class GroupTables(NamedTuple):
    """Per-group platform tables + the static allocation order.

    ``perm`` is the one per-*node* member: the host-precomputed stable
    argsort of ``(order_key, nid)`` (identity for ``node_order="id"`` and
    the dynamic ``"pack"`` key). Under a statically-eager policy every
    eligible node is ready at ``t``, so ``perm`` IS the allocation order
    and the scheduler pass runs sort-free; transition-aware/traced
    policies re-sort ``perm`` by ready time once per pass.
    """

    count: jax.Array  # i32[G] nodes per group
    start: jax.Array  # i32[G] first node id of group (ids contiguous)
    power: jax.Array  # f32[G, 5] per-state watts
    t_on: jax.Array  # i32[G] switch-on delay (s)
    t_off: jax.Array  # i32[G] switch-off delay (s)
    speed: jax.Array  # f32[G] compute speed
    order_key: jax.Array  # f32[G] allocation preference (lower first)
    perm: jax.Array  # i32[N] static node order by (order_key, nid)


def _uniform_rows(name: str, table: np.ndarray, gid: np.ndarray, G: int):
    """First row of each group, verifying the table is constant per group."""
    starts = np.searchsorted(gid, np.arange(G))
    rep = table[starts]
    if not np.array_equal(table, rep[gid]):
        raise ValueError(
            f"grouped tables need per-group-uniform platforms, but "
            f"{name!r} varies within a node group (per-node JSON platforms "
            "with intra-group variation must use the dense path: "
            "EngineConfig(grouped_tables=False))"
        )
    return rep


def group_tables(platform: PlatformSpec, config) -> GroupTables:
    """Lower ``platform`` to :class:`GroupTables` (host-side numpy).

    ``config`` contributes only ``node_order`` — the spelling of the
    static allocation key, matching ``engine.make_const``'s dense
    ``order_key``: ``"idle-watts"`` keys on idle draw, ``"cheap"`` on
    active watts per unit work, and ``"id"``/``"pack"`` carry no static
    key (identity ``perm``; ``"pack"``'s key is per-pass dynamic state).
    """
    N = platform.nb_nodes
    G = platform.n_groups()
    gid = np.asarray(platform.node_group_id(), np.int32)
    counts = np.bincount(gid, minlength=G).astype(np.int32)
    starts = np.concatenate(([0], np.cumsum(counts)[:-1])).astype(np.int32)
    if platform.node_groups:
        power = _uniform_rows(
            "power", np.asarray(platform.node_power_table(), np.float32),
            gid, G,
        )
        t_on = _uniform_rows(
            "t_on", np.asarray(platform.node_t_switch_on(), np.int32), gid, G
        )
        t_off = _uniform_rows(
            "t_off", np.asarray(platform.node_t_switch_off(), np.int32),
            gid, G,
        )
        speed = _uniform_rows(
            "speed", np.asarray(platform.node_speed(), np.float32), gid, G
        )
    else:
        power = np.asarray(platform.power_table(), np.float32)[None, :]
        t_on = np.asarray([platform.t_switch_on], np.int32)
        t_off = np.asarray([platform.t_switch_off], np.int32)
        speed = np.asarray([platform.speed()], np.float32)
    # the same f32 key expressions as engine.make_const's dense order_key
    if config.node_order == "idle-watts":
        okey_g = power[:, IDLE].astype(np.float32)
    else:
        okey_g = (power[:, ACTIVE] / speed).astype(np.float32)
    if config.node_order in ("id", "pack"):
        # no static key: identity order (ties by node id); "pack"'s
        # fewest-idle key is dynamic state, re-keyed per scheduler pass
        perm = np.arange(N, dtype=np.int32)
    else:
        perm = np.argsort(okey_g[gid], kind="stable").astype(np.int32)
    return GroupTables(
        count=jnp.asarray(counts),
        start=jnp.asarray(starts),
        power=jnp.asarray(power),
        t_on=jnp.asarray(t_on),
        t_off=jnp.asarray(t_off),
        speed=jnp.asarray(speed),
        order_key=jnp.asarray(okey_g),
        perm=jnp.asarray(perm),
    )
