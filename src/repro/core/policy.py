"""Declarative power-policy layer with a *traced* policy axis
(core/SEMANTICS.md §Traced policy axis).

PR 2 made power management composable: each policy contributed JAX hooks
(``post_schedule``/``next_event_candidates``/...) that were compiled *into*
the engine, so a scheduler x policy grid still compiled one XLA program per
policy stack. Here the static structure of every stack is lowered into
:class:`PolicyParams` — a NamedTuple of **traced flags** carried in
``EngineConst`` — and both engines evaluate one flag-gated *superset*
program:

* ``backfill``      — EASY backfilling (False = FCFS stop-at-head), rule 4,
* ``eager_ready``   — scheduling ignores power states (ready-time table),
* ``sleep_enabled`` — rule 6 (idle-timeout switch-off) is active,
* ``ipm_enabled``   — rule 6's demand cap + rule 7 (proactive wake),
* ``rl_enabled``    — rule 8 (agent power commands) is active,
* ``rl_grouped``    — rule 8 selects within node groups,
* ``dvfs_enabled``  — rule 9 (runtime per-group DVFS mode switching),
* ``dvfs_rl``       — rule 9 modes come from agent commands, not the ladder,
* ``forecast_enabled`` — rule 10 (EWMA arrival-pressure forecast: proactive
  node wake-up ahead of predicted demand),
* ``forecast_dvfs`` — rule 10 also pre-ramps DVFS modes toward the
  forecast-adjusted ladder (never below rule 9's current choice).

Because the flags are traced operands (not static config), a whole
scheduler x policy x timeout grid vmaps through ONE compiled program
(``engine.sweep`` / ``repro.experiments``), bit-exact with the per-config
compiles it replaces. A :class:`PowerPolicy` is now purely declarative: it
*names* a point on the traced axis via :meth:`PowerPolicy.params`. Adding a
genuinely new power-management *rule* (not a new combination) means
extending the superset: a new flag here, its gate in both engines, and a
SEMANTICS.md entry — that is the deliberate price of the one-compile grid.

The only remaining static policy structure is ``RLController.controller``:
an in-graph policy network cannot be a traced operand.

``PSMVariant`` survives only as a deprecation shim (``policy_from_psm``);
``from_label`` is the single scheduler-string registry consumed by
``launch/sim.py``, ``repro.experiments``, the benchmarks, and the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (
    ACTIVE,
    IDLE,
    INF_TIME,
    RUNNING,
    SLEEP,
    SWITCHING_OFF,
    SWITCHING_ON,
    WAITING,
    BasePolicy,
    PSMVariant,
    did_you_mean,
)

I32 = jnp.int32
INF = jnp.asarray(INF_TIME, I32)


class PolicyParams(NamedTuple):
    """The traced policy axis: per-scenario behaviour flags (all bool).

    Members are JAX-traced operands inside the engine (``EngineConst.policy``)
    and plain Python bools on the oracle side (``PyDES.pp``); identical
    semantics either way (core/SEMANTICS.md §Traced policy axis). Sweeping
    any of these — i.e. sweeping schedulers/policies — never recompiles.
    """

    backfill: Any  # EASY backfilling; False = FCFS stop-at-head (rule 4)
    eager_ready: Any  # scheduling ignores power states (ready-time table)
    sleep_enabled: Any  # rule 6 active (idle-timeout switch-off)
    ipm_enabled: Any  # rule 6 demand cap + rule 7 proactive wake
    rl_enabled: Any  # rule 8 active (agent power commands)
    rl_grouped: Any  # rule 8 selects per node group
    dvfs_enabled: Any  # rule 9 active (runtime per-group DVFS switching)
    dvfs_rl: Any  # rule 9 modes from agent commands (else pressure ladder)
    forecast_enabled: Any  # rule 10 active (EWMA forecast, proactive wake)
    forecast_dvfs: Any  # rule 10 also pre-ramps DVFS modes (needs rule 9)

    def traced(self) -> "PolicyParams":
        """The jnp.bool_ spelling carried in EngineConst (vmap-stackable)."""
        return PolicyParams(*[jnp.asarray(bool(v)) for v in self])

    def static(self) -> "PolicyParams":
        """The concrete Python-bool spelling (single-config specialization).

        A static PolicyParams is closed over as trace *structure*, not a
        traced operand: the engine's flag accessors (:func:`static_bool`)
        turn each ``jnp.where`` gate into a Python branch, so XLA never
        even sees the rules that are off (core/SEMANTICS.md §Static
        specialization). Hashable — part of the simulate() jit-cache key.
        """
        return PolicyParams(*[bool(v) for v in self])


def static_bool(flag) -> Optional[bool]:
    """The engine's flag accessor: Python bool when ``flag`` is concrete
    (the specialized single-config path — callers then prune the dead
    branch at trace time), None when it is a traced operand (the sweep
    axis — callers keep the ``jnp.where`` superset gate)."""
    if isinstance(flag, (bool, np.bool_)):
        return bool(flag)
    return None


# ---------------------------------------------------------------------------
# shared rule implementations (SEMANTICS.md rules 6-8), flag-gated
# ---------------------------------------------------------------------------
#
# ``enabled`` / ``ipm_cap`` / ``grouped`` accept Python bools (specialized
# call sites: the RL env applies commands unconditionally) *or* traced
# scalars (the engine's superset power step). A disabled rule selects no
# nodes and leaves every state array and counter bit-identical.

def queued_demand(s) -> jax.Array:
    waiting = (s.job_status == WAITING) & (s.job_subtime <= s.t)
    return jnp.sum(jnp.where(waiting, s.job_res, 0))


def timeout_switch_off(s, const, ipm_cap, enabled=True):
    """Rule 6: switch off expired idle nodes, longest-idle first (ties by id).

    ``ipm_cap`` (PSAS+IPM) caps the count so available capacity never drops
    below queued demand. Both gates may be traced.
    """
    cand = (
        (s.node_job < 0)
        & (s.node_state == IDLE)
        & (s.t - s.node_idle_since >= const.timeout)
        & enabled
    )
    cap = static_bool(ipm_cap)
    if cap is False:
        # uncapped (statically): k = min(n_cand, N) = n_cand, so the
        # k-longest-idle selection is provably "every candidate" — the
        # O(N log N) argsort is dead. Bit-exact with the capped spelling;
        # this is the PSUS/PSAS hot path (core/SEMANTICS.md §Hot loop).
        sel = cand
    else:
        n_cand = jnp.sum(cand, dtype=I32)
        avail = jnp.sum(
            (s.node_job < 0)
            & ((s.node_state == IDLE) | (s.node_state == SWITCHING_ON)),
            dtype=I32,
        )
        if cap is None:  # traced: evaluate both columns, select per scenario
            allowed = jnp.where(
                ipm_cap,
                jnp.maximum(avail - queued_demand(s), 0),
                jnp.asarray(s.node_state.shape[0], I32),
            )
        else:
            allowed = jnp.maximum(avail - queued_demand(s), 0)
        k = jnp.minimum(n_cand, allowed)
        key = jnp.where(cand, s.node_idle_since, INF)  # longest idle first
        order = jnp.argsort(key, stable=True)
        sel_sorted = jnp.arange(key.shape[0]) < k
        sel = jnp.zeros_like(cand).at[order].set(sel_sorted) & cand
    return s._replace(
        node_state=jnp.where(sel, SWITCHING_OFF, s.node_state),
        node_until=jnp.where(sel, s.t + const.t_off, s.node_until),
        n_switch_off=s.n_switch_off + jnp.sum(sel, dtype=I32),
    )


def ipm_wake(s, const, enabled=True):
    """Rule 7: wake sleeping nodes (lowest id first) to cover queued demand."""
    avail = jnp.sum(
        (s.node_job < 0)
        & ((s.node_state == IDLE) | (s.node_state == SWITCHING_ON)),
        dtype=I32,
    )
    deficit = queued_demand(s) - avail
    cand = (s.node_job < 0) & (s.node_state == SLEEP)
    sel = cand & (jnp.cumsum(cand) <= deficit) & enabled  # lowest id first
    return s._replace(
        node_state=jnp.where(sel, SWITCHING_ON, s.node_state),
        node_until=jnp.where(sel, s.t + const.t_on, s.node_until),
        n_switch_on=s.n_switch_on + jnp.sum(sel, dtype=I32),
    )


def pack_key(s, const):
    """f32[N] queue-aware allocation key for ``node_order="pack"``.

    Prefer groups with the FEWEST currently-idle unreserved nodes, so jobs
    pack into nearly-full groups and lightly-used groups drain to empty —
    whole-group sleepable under rule 6 (core/SEMANTICS.md §Node selection
    order). Nodes that are idle-and-unreserved right now sort strictly
    before every other eligible node (sleeping/transitioning nodes carry a
    ``N + 1`` band offset), so packing never wakes a sleeping group while
    idle capacity remains. Recomputed ONCE per scheduler pass and frozen
    across the pass's attempts (the loop-invariance the grouped hoisted
    order requires; the oracle's ``_pack_key`` freezes identically).
    Exact in f32: values are integer counts plus one band offset,
    <= 2N + 1 << 2**24. Twin of the oracle's ``_pack_key``.
    """
    G = s.energy.shape[0]
    N = s.node_state.shape[0]
    idle_unres = (s.node_job < 0) & (s.node_state == IDLE)
    counts = (
        jnp.zeros(G, jnp.float32)
        .at[const.group_id]
        .add(idle_unres.astype(jnp.float32))
    )
    band = jnp.where(idle_unres, jnp.float32(0), jnp.float32(N + 1))
    return counts[const.group_id] + band


def _select_longest_idle(cand, idle_since, k):
    """Boolean mask of the k longest-idle candidates (ties by node id)."""
    key = jnp.where(cand, idle_since, INF)
    order = jnp.argsort(key, stable=True)
    k = jnp.minimum(jnp.sum(cand, dtype=I32), k)
    sel_sorted = jnp.arange(key.shape[0]) < k
    return jnp.zeros_like(cand).at[order].set(sel_sorted) & cand


def apply_rl_commands(s, const, grouped=False, enabled=True):
    """Rule 8: apply pending RL power commands, then clear them.

    ``rl_on_cmd``/``rl_off_cmd`` are ``i32[G]`` per-group command vectors.

    * global mode (``grouped=False``): the effective counts are the vector
      sums; selection is cluster-wide (wake lowest-id sleeping, sleep
      longest-idle unreserved-idle) — bit-exact with the legacy scalar
      commands.
    * grouped mode: each group g wakes up to ``on[g]`` of *its* sleeping
      nodes (lowest id first) and sleeps up to ``off[g]`` of *its* unreserved
      idle nodes (longest idle first); groups are independent, so the
      expensive island can be slept while the cheap one is woken in one step.

    ``grouped`` may be a Python bool (specialized: the RL env's command
    application) or a traced scalar (the engine's superset power step, which
    then evaluates both selection modes and selects per scenario).
    """
    cand_on = (s.node_job < 0) & (s.node_state == SLEEP)
    cand_off = (s.node_job < 0) & (s.node_state == IDLE)
    G = s.rl_on_cmd.shape[0]

    def _grouped():
        same = const.group_id[None, :] == jnp.arange(G, dtype=I32)[:, None]
        ranks_on = jnp.cumsum(cand_on[None, :] & same, axis=1)  # [G, N]
        on = cand_on & jnp.any(same & (ranks_on <= s.rl_on_cmd[:, None]), axis=0)
        off_g = jax.vmap(_select_longest_idle, in_axes=(0, None, 0))(
            cand_off[None, :] & same, s.node_idle_since, s.rl_off_cmd
        )
        return on, jnp.any(off_g, axis=0)

    def _global():
        on = cand_on & (jnp.cumsum(cand_on) <= jnp.sum(s.rl_on_cmd))
        off = _select_longest_idle(
            cand_off, s.node_idle_since, jnp.sum(s.rl_off_cmd)
        )
        return on, off

    if isinstance(grouped, bool):  # specialized call site: one mode only
        sel_on, sel_off = _grouped() if grouped else _global()
    else:  # traced flag: evaluate both modes, select per scenario
        on_g, off_g = _grouped()
        on_gl, off_gl = _global()
        sel_on = jnp.where(grouped, on_g, on_gl)
        sel_off = jnp.where(grouped, off_g, off_gl)
    sel_on = sel_on & enabled
    sel_off = sel_off & enabled
    state = jnp.where(sel_on, SWITCHING_ON, s.node_state)
    state = jnp.where(sel_off, SWITCHING_OFF, state)
    until = jnp.where(sel_on, s.t + const.t_on, s.node_until)
    until = jnp.where(sel_off, s.t + const.t_off, until)
    return s._replace(
        node_state=state,
        node_until=until,
        rl_on_cmd=jnp.where(enabled, jnp.zeros(G, I32), s.rl_on_cmd),
        rl_off_cmd=jnp.where(enabled, jnp.zeros(G, I32), s.rl_off_cmd),
        n_switch_on=s.n_switch_on + jnp.sum(sel_on, dtype=I32),
        n_switch_off=s.n_switch_off + jnp.sum(sel_off, dtype=I32),
    )


def effective_node_speed(const, mode, enabled):
    """f32[N] node speed under DVFS mode vector ``mode`` (i32[G]); the base
    ``const.speed`` when ``enabled`` is off. The single spelling of the
    current-operating-point speed shared by job start (rule 5) and the
    rescale (rule 9)."""
    sb = static_bool(enabled)
    if sb is False:
        return const.speed
    table = const.dvfs_speed[const.group_id, mode[const.group_id]]
    if sb is True:
        return table
    return jnp.where(enabled, table, const.speed)


def alloc_min_speed(node_job, node_speed, n_jobs):
    """f32[J] min node speed over each job's allocated nodes (inf when the
    job holds none) — the cross-engine realized-runtime contract's scatter
    (core/SEMANTICS.md §Heterogeneity / §DVFS)."""
    cj = jnp.maximum(node_job, 0)
    return jnp.full(n_jobs, jnp.inf, jnp.float32).at[cj].min(
        jnp.where(node_job >= 0, node_speed, jnp.inf)
    )


def apply_dvfs_modes(s, const, target, enabled, terminate_overrun=False):
    """Install DVFS mode vector ``target`` (i32[G]) where ``enabled`` and
    rescale remaining work — the shared tail of rules 9 and 10.

    Remaining-work rescale: every RUNNING, non-terminated job whose
    allocation's effective speed changed gets its remaining wall time
    rescaled by the f32 contract expression
    ``max(ceil((f32(finish - t) * old_speed) / new_speed), 1)``; under
    ``terminate_overrun`` the new finish is capped at ``start + reqtime``
    (walltime is a user clock, it never scales) and the job is marked
    terminated when the cap bites. Leaves ``rl_mode_cmd`` alone (rule 9
    clears it at its own call site). Twin of the oracle's
    ``_apply_dvfs_modes``.
    """
    mode = jnp.where(enabled, target, s.dvfs_mode)

    # effective per-node speed under the (possibly new) mode vector
    eff = effective_node_speed(const, mode, enabled)
    J = s.job_status.shape[0]
    alloc_min = alloc_min_speed(s.node_job, eff, J)
    running = (s.job_status == RUNNING) & ~s.job_terminated
    speed_min = jnp.where(running, alloc_min, s.job_speed)
    changed = running & (speed_min != s.job_speed) & enabled
    rem = jnp.maximum(s.job_finish - s.t, 1).astype(jnp.float32)
    work = rem * s.job_speed  # f32 remaining work (contract expression)
    new_rem = jnp.maximum(jnp.ceil(work / speed_min).astype(I32), 1)
    new_finish = s.t + new_rem
    terminated = s.job_terminated
    if terminate_overrun:
        cap = s.job_start + s.job_reqtime
        capped = changed & (new_finish > cap)
        new_finish = jnp.minimum(new_finish, cap)
        terminated = terminated | capped
    finish = jnp.where(changed, new_finish, s.job_finish)
    return s._replace(
        dvfs_mode=mode,
        job_speed=jnp.where(running & enabled, speed_min, s.job_speed),
        job_finish=finish,
        job_eff=jnp.where(changed, finish - s.job_start, s.job_eff),
        job_terminated=terminated,
    )


def apply_dvfs(s, const, terminate_overrun=False, enabled=True, rl=False):
    """Rule 9: per-group DVFS mode selection + remaining-work rescale.

    Mode selection (core/SEMANTICS.md §DVFS):

    * heuristic ladder (``rl=False``): group g's mode index is the integer
      ``min(n_modes[g] - 1, demand * n_modes[g] // N)`` where ``demand`` is
      the cluster's queued resource demand — an empty queue idles every
      group at its slowest mode, a saturated queue runs them at the fastest.
    * agent-commanded (``rl=True``): the pending ``rl_mode_cmd`` vector
      (i32[G], -1 = no change) is applied, clamped per group, then cleared.

    The mode install + remaining-work rescale is :func:`apply_dvfs_modes`
    (shared with rule 10's pre-ramp). ``enabled``/``rl`` may be traced flags
    (the engine's superset power step) or Python bools (the RL env).
    """
    G, _ = const.dvfs_speed.shape
    N = s.node_state.shape[0]
    n_modes = const.dvfs_n_modes
    rl_b = static_bool(rl)
    if rl_b is not True:
        ladder = jnp.minimum(n_modes - 1, (queued_demand(s) * n_modes) // N)
    if rl_b is not False:
        commanded = jnp.where(
            s.rl_mode_cmd >= 0,
            jnp.clip(s.rl_mode_cmd, 0, n_modes - 1),
            s.dvfs_mode,
        )
    if rl_b is None:  # traced: both selectors, chosen per scenario
        target = jnp.where(rl, commanded, ladder).astype(I32)
    else:
        target = (commanded if rl_b else ladder).astype(I32)
    s = apply_dvfs_modes(s, const, target, enabled, terminate_overrun)
    return s._replace(
        rl_mode_cmd=jnp.where(enabled, jnp.full(G, -1, I32), s.rl_mode_cmd),
    )


def forecast_pressure(s, const):
    """i32 predicted extra node demand over the forecast horizon (rule 10).

    The EWMA predictor state (``fc_gap``: smoothed inter-arrival gap,
    ``fc_res``: smoothed nodes requested per arrival) extrapolates linearly:
    ``horizon / gap`` arrivals expected within the horizon, each asking for
    ``fc_res`` nodes — floored to an integer and clipped to the cluster
    size. A zero horizon (or a predictor that never saw an arrival: ``gap``
    still at its INF_TIME init) predicts zero. Twin of the oracle's
    ``_forecast_pressure``.
    """
    gap = jnp.maximum(s.fc_gap, jnp.float32(1.0))
    horizon = const.forecast_horizon.astype(jnp.float32)
    pressure = (horizon / gap) * s.fc_res
    # clip in f32 BEFORE the i32 cast: an extreme horizon/gap ratio must
    # saturate at N, not wrap through integer overflow
    N = s.node_state.shape[0]
    return jnp.clip(jnp.floor(pressure), 0.0, jnp.float32(N)).astype(I32)


def apply_forecast(s, const, terminate_overrun=False, enabled=True,
                   dvfs_ramp=False):
    """Rule 10: EWMA arrival-pressure forecast — proactive wake + DVFS ramp.

    Predictor update (core/SEMANTICS.md §Forecast): arrivals with
    ``fc_prev_t < subtime <= t`` are this batch's new-arrival burst; the
    observed per-arrival gap ``(t - fc_last_arr) / n_new`` and per-arrival
    resource ask feed strict-form EWMAs ``a*obs + (1-a)*ewma`` (no
    first-observation seeding, so ``alpha=0`` provably freezes the init
    values and the rule is a no-op).

    Proactive wake: predicted pressure ``f_extra`` widens rule 7's deficit
    — sleeping nodes are switched on (lowest id first) until unreserved
    IDLE/SWITCHING_ON capacity covers ``queued_demand + f_extra``. Fires
    only when ``f_extra > 0``, so a zero-horizon Forecast stack is
    bit-exact with its reactive base rather than degenerating into IPM.

    DVFS pre-ramp (``dvfs_ramp``, stacks with rule 9 composed): groups ramp
    toward the forecast-adjusted ladder
    ``min(n_modes - 1, (demand + f_extra) * n_modes // N)`` but never below
    rule 9's current choice; the install + rescale is the shared
    :func:`apply_dvfs_modes` contract. ``enabled``/``dvfs_ramp`` may be
    traced flags or Python bools. Twin of the oracle's ``_apply_forecast``.
    """
    # --- predictor update (EWMA over this batch's arrival burst) ---
    newly = (
        s.job_exists & (s.job_subtime <= s.t) & (s.job_subtime > s.fc_prev_t)
    )
    n_new = jnp.sum(newly, dtype=I32)
    denom = jnp.maximum(n_new, 1).astype(jnp.float32)
    gap_obs = (s.t - s.fc_last_arr).astype(jnp.float32) / denom
    res_obs = (
        jnp.sum(jnp.where(newly, s.job_res, 0), dtype=I32).astype(jnp.float32)
        / denom
    )
    a = const.forecast_alpha
    one = jnp.float32(1.0)
    upd = enabled & (n_new > 0)
    s = s._replace(
        fc_gap=jnp.where(upd, a * gap_obs + (one - a) * s.fc_gap, s.fc_gap),
        fc_res=jnp.where(upd, a * res_obs + (one - a) * s.fc_res, s.fc_res),
        fc_last_arr=jnp.where(upd, s.t, s.fc_last_arr),
        fc_prev_t=jnp.where(enabled, s.t, s.fc_prev_t),
    )

    # --- proactive wake: cover predicted demand beyond current capacity ---
    f_extra = forecast_pressure(s, const)
    avail = jnp.sum(
        (s.node_job < 0)
        & ((s.node_state == IDLE) | (s.node_state == SWITCHING_ON)),
        dtype=I32,
    )
    deficit = queued_demand(s) + f_extra - avail
    cand = (s.node_job < 0) & (s.node_state == SLEEP)
    sel = cand & (jnp.cumsum(cand) <= deficit) & (f_extra > 0) & enabled
    s = s._replace(
        node_state=jnp.where(sel, SWITCHING_ON, s.node_state),
        node_until=jnp.where(sel, s.t + const.t_on, s.node_until),
        n_switch_on=s.n_switch_on + jnp.sum(sel, dtype=I32),
    )

    # --- DVFS pre-ramp: never below rule 9's current mode ---
    if static_bool(dvfs_ramp) is False:
        return s
    N = s.node_state.shape[0]
    n_modes = const.dvfs_n_modes
    fc_mode = jnp.minimum(
        n_modes - 1, ((queued_demand(s) + f_extra) * n_modes) // N
    )
    target = jnp.maximum(s.dvfs_mode, fc_mode.astype(I32))
    ramp_on = dvfs_ramp & enabled & (f_extra > 0)
    return apply_dvfs_modes(s, const, target, ramp_on, terminate_overrun)


# ---------------------------------------------------------------------------
# the declarative policy stacks
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PowerPolicy:
    """Base declarative policy: a no-op power manager (never sleeps anything).

    A policy names a point on the traced policy axis via :meth:`params`;
    the engines contain the (flag-gated) rule implementations. Policies are
    hashable frozen dataclasses, so an ``EngineConfig`` remains a valid jit
    cache key; they carry no trace structure except an optional in-graph
    ``controller`` (RL).

    ``dvfs=True`` composes runtime per-group DVFS mode switching (rule 9,
    §DVFS) onto any stack: the queue-pressure ladder by default, agent
    commands under :class:`RLController`. ``forecast=True`` composes the
    EWMA arrival-pressure forecaster (rule 10, §Forecast) the same way —
    proactive wake-ups, plus DVFS pre-ramp when rule 9 is also on.
    """

    dvfs: bool = False
    forecast: bool = False

    @property
    def eager_ready(self) -> bool:
        """True: scheduling treats every non-ACTIVE node as ready at t."""
        return True

    def flags(self) -> dict:
        """Rule-enable flags this stack contributes (see PolicyParams)."""
        return dict(
            sleep_enabled=False,
            ipm_enabled=False,
            rl_enabled=False,
            rl_grouped=False,
            dvfs_enabled=self.dvfs,
            dvfs_rl=False,
            forecast_enabled=self.forecast,
            forecast_dvfs=self.forecast and self.dvfs,
        )

    def params(self, base: BasePolicy = BasePolicy.EASY) -> PolicyParams:
        """Lower (base, self) onto the traced policy axis."""
        return PolicyParams(
            backfill=(BasePolicy(base) == BasePolicy.EASY),
            eager_ready=self.eager_ready,
            **self.flags(),
        )

    def _base_label(self) -> str:
        return "AlwaysOn"

    def psm_label(self) -> str:
        lbl = self._base_label()
        if self.dvfs:
            lbl += "+DVFS"
        if self.forecast:
            lbl += "+Forecast"
        return lbl


@dataclasses.dataclass(frozen=True)
class AlwaysOn(PowerPolicy):
    """Classic always-on baseline: nodes never sleep (legacy PSM ``NONE``)."""


@dataclasses.dataclass(frozen=True)
class DVFS(PowerPolicy):
    """Queue-pressure DVFS ladder on always-on nodes (rule 9, §DVFS): each
    decision point sets every group's mode to
    ``min(n_modes - 1, demand * n_modes // N)`` — slowest when the queue is
    empty, fastest when demand saturates the cluster. Compose DVFS onto a
    sleeping stack with e.g. ``TimeoutSleep(dvfs=True)`` ("PSUS+DVFS")."""

    dvfs: bool = True

    def psm_label(self) -> str:
        return "DVFS+Forecast" if self.forecast else "DVFS"


@dataclasses.dataclass(frozen=True)
class TimeoutSleep(PowerPolicy):
    """Idle-timeout switch-off (legacy PSUS / PSAS).

    ``transition_aware=False`` (PSUS): scheduling ignores power states — jobs
    simply wait for rule-5 wake-ups. ``transition_aware=True`` (PSAS
    "Auto On"): ready times account for transition delays (the SEMANTICS.md
    variant table's right column).
    """

    transition_aware: bool = False

    @property
    def eager_ready(self) -> bool:
        return not self.transition_aware

    def flags(self) -> dict:
        return {**super().flags(), "sleep_enabled": True}

    def _base_label(self) -> str:
        return "PSAS(AutoOn)" if self.transition_aware else "PSUS"


@dataclasses.dataclass(frozen=True)
class IPM(TimeoutSleep):
    """TimeoutSleep + intelligent power management (legacy PSAS+IPM):
    switch-offs are capped by queued demand and sleeping nodes are woken
    proactively when demand exceeds available capacity."""

    transition_aware: bool = True

    def flags(self) -> dict:
        return {**super().flags(), "ipm_enabled": True}

    def _base_label(self) -> str:
        return "PSAS+IPM"


@dataclasses.dataclass(frozen=True)
class Forecast(PowerPolicy):
    """EWMA arrival-pressure forecaster (rule 10, §Forecast) as a
    standalone stack: proactive wake-ups on otherwise always-on nodes.
    Compose it onto a reactive stack with ``"<PSM>+Forecast"`` labels
    (e.g. ``"EASY PSUS+Forecast"`` = ``TimeoutSleep(forecast=True)``),
    exactly like ``"+DVFS"``.

    ``horizon``/``alpha`` are *defaults* for the traced EngineConst
    operands: ``EngineConfig.forecast_horizon``/``forecast_alpha`` win when
    set, and horizon sweeps override per scenario (the numbers ride the
    traced axis; only the ``forecast`` enable flag is policy structure,
    mirroring how ``TimeoutSleep`` declares rule 6 while ``timeout``
    carries the number).
    """

    forecast: bool = True
    horizon: Optional[int] = None
    alpha: Optional[float] = None

    def psm_label(self) -> str:
        return "DVFS+Forecast" if self.dvfs else "Forecast"


@dataclasses.dataclass(frozen=True)
class RLController(PowerPolicy):
    """Agent-controlled power commands (legacy PSM ``RL``).

    ``grouped=False``: commands are global counts (sum over the ``[G]``
    command vectors) — the checkpoint-compatible default. ``grouped=True``:
    commands target node groups individually (see ``apply_rl_commands``).

    ``controller``: optional in-graph policy ``f(s, const) -> (on[G], off[G])``
    — or ``(on[G], off[G], mode[G])`` when ``dvfs=True`` (mode -1 = no
    change) — evaluated inside the engine's power step; this is how a
    checkpointed network drives ``run_sim`` end-to-end as one compiled
    program (``launch/sim.py``). When None, pending commands set externally
    (the RL env path) are applied. The controller is the one piece of policy
    structure that stays *static*: a network cannot be a traced flag.

    ``dvfs=True`` ("RL:dvfs"): rule 9's per-group modes come from the
    agent's mode commands instead of the queue-pressure ladder.
    """

    grouped: bool = False
    controller: Optional[Callable] = None

    def flags(self) -> dict:
        return {
            **super().flags(),
            "rl_enabled": True,
            "rl_grouped": self.grouped,
            "dvfs_rl": self.dvfs,
        }

    def psm_label(self) -> str:
        base = "RL:groups" if self.grouped else "RL"
        if self.dvfs:
            base = "RL:dvfs" if not self.grouped else f"{base}+DVFS"
        return f"{base}+Forecast" if self.forecast else base


# ---------------------------------------------------------------------------
# deprecation shim: PSMVariant <-> PowerPolicy
# ---------------------------------------------------------------------------

_PSM_TO_POLICY = {
    PSMVariant.NONE: AlwaysOn(),
    PSMVariant.PSUS: TimeoutSleep(),
    PSMVariant.PSAS: TimeoutSleep(transition_aware=True),
    PSMVariant.PSAS_IPM: IPM(),
    PSMVariant.RL: RLController(),
}


def policy_from_psm(psm: PSMVariant) -> PowerPolicy:
    """Legacy ``EngineConfig(psm=...)`` -> the equivalent policy stack."""
    return _PSM_TO_POLICY[PSMVariant(psm)]


def psm_of(policy: PowerPolicy) -> Optional[PSMVariant]:
    """Best-effort reverse map (None for policies with no legacy twin)."""
    if getattr(policy, "dvfs", False) or getattr(policy, "forecast", False):
        return None  # runtime DVFS / forecast postdate the PSMVariant enum
    if isinstance(policy, RLController):
        return PSMVariant.RL
    if isinstance(policy, IPM):
        return PSMVariant.PSAS_IPM
    if isinstance(policy, TimeoutSleep):
        return (
            PSMVariant.PSAS if policy.transition_aware else PSMVariant.PSUS
        )
    if isinstance(policy, AlwaysOn):
        return PSMVariant.NONE
    return None


# ---------------------------------------------------------------------------
# scheduler-label registry (single source of truth for launch/benchmarks)
# ---------------------------------------------------------------------------

_BASE_TOKENS = {"FCFS": BasePolicy.FCFS, "EASY": BasePolicy.EASY}
_PSM_TOKENS = {
    "PSUS": TimeoutSleep(),
    "PSAS": TimeoutSleep(transition_aware=True),
    "PSAS(AUTOON)": TimeoutSleep(transition_aware=True),  # alias
    "PSAS+IPM": IPM(),
    "ALWAYSON": AlwaysOn(),
    "DVFS": DVFS(),
    "FORECAST": Forecast(),
    "RL": RLController(),
    "RL:GROUPS": RLController(grouped=True),
    "RL:DVFS": RLController(dvfs=True),
}
_CANONICAL_PSM = ("PSUS", "PSAS", "PSAS+IPM", "AlwaysOn")
_CANONICAL_RL = ("RL", "RL:groups")
_CANONICAL_DVFS = ("DVFS",)
_CANONICAL_FORECAST = ("Forecast", "PSUS+Forecast")


def _resolve_psm_token(token: str) -> Optional[PowerPolicy]:
    psm = _PSM_TOKENS.get(token)
    if psm is not None:
        return psm
    # generic rule composition: "<PSM>+DVFS" / "<PSM>+Forecast" turn rules
    # 9 / 10 on over any registered stack, recursively so the suffixes
    # stack in either order ("PSUS+DVFS+FORECAST", "PSAS+IPM+FORECAST+DVFS")
    for suffix, field in (("+DVFS", "dvfs"), ("+FORECAST", "forecast")):
        if token.endswith(suffix):
            base = _resolve_psm_token(token[: -len(suffix)])
            if base is not None:
                return dataclasses.replace(base, **{field: True})
    return None


def from_label(label: str) -> Tuple[BasePolicy, PowerPolicy]:
    """Parse ``"<FCFS|EASY> <PSM>"`` into a (base, policy) pair.

    PSM tokens: PSUS | PSAS | PSAS(AutoOn) | PSAS+IPM | AlwaysOn | DVFS |
    Forecast | RL | RL:groups | RL:dvfs, plus ``<PSM>+DVFS`` /
    ``<PSM>+Forecast`` suffixes (stackable, either order) for any of them
    (case-insensitive).
    """
    parts = label.split()
    if len(parts) == 2 and parts[0].upper() in _BASE_TOKENS:
        psm = _resolve_psm_token(parts[1].upper())
        if psm is not None:
            return _BASE_TOKENS[parts[0].upper()], psm
    known = scheduler_labels(
        include_rl=True, include_dvfs=True, include_forecast=True
    )
    raise KeyError(
        f"unknown scheduler label {label!r}{did_you_mean(label, known)}; "
        f"expected one of {', '.join(known)} "
        "(alias: 'PSAS(AutoOn)' for PSAS; '<PSM>+DVFS' / '<PSM>+Forecast' "
        "compose rules 9 / 10 onto any stack)"
    )


def scheduler_labels(
    include_rl: bool = False,
    include_dvfs: bool = False,
    include_forecast: bool = False,
) -> Tuple[str, ...]:
    """Canonical labels, in the order the paper's figures use."""
    psms = (
        _CANONICAL_PSM
        + (_CANONICAL_DVFS if include_dvfs else ())
        + (_CANONICAL_FORECAST if include_forecast else ())
        + (_CANONICAL_RL if include_rl else ())
        + (("RL:dvfs",) if include_rl and include_dvfs else ())
    )
    return tuple(
        f"{base} {psm}" for psm in psms for base in ("FCFS", "EASY")
    )


def label_of(base: BasePolicy, policy: PowerPolicy) -> str:
    b = "FCFS" if base == BasePolicy.FCFS else "EASY"
    p = policy.psm_label().replace("PSAS(AutoOn)", "PSAS")
    return f"{b} {p}"
