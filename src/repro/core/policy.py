"""Composable power-policy layer (core/SEMANTICS.md §Policy hooks).

The engines used to branch on a ``PSMVariant`` enum in five separate
functions; every new power-management idea meant editing the engine core.
Here each policy is a frozen config dataclass that contributes three hooks,
composed by ``engine.process_batch`` / ``PyDES._process_batch``:

* ``eager_ready``           — scheduling ignores power states (the PSUS-family
                              fast path of the ready-time table),
* ``post_schedule``         — the power-management step after job starts
                              (SEMANTICS.md rules 6-8: switch-off / wake / RL),
* ``next_event_candidates`` — extra wake-up times for the time advance.

Each hook has a JAX implementation (operating on ``SimState``) and a ``_ref``
twin operating on the sequential Python oracle (``core/ref/pydes.py``) —
both engines stay bit-exact per policy, enforced by the parity suite.
Policies are static engine configuration: hashable frozen dataclasses, so an
``EngineConfig`` remains a valid jit cache key.

``PSMVariant`` survives only as a deprecation shim (`policy_from_psm`);
``from_label`` is the single scheduler-string registry consumed by
``launch/sim.py``, the benchmarks, and the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    ACTIVE,
    IDLE,
    INF_TIME,
    SLEEP,
    SWITCHING_OFF,
    SWITCHING_ON,
    WAITING,
    BasePolicy,
    PSMVariant,
)

I32 = jnp.int32
INF = jnp.asarray(INF_TIME, I32)


# ---------------------------------------------------------------------------
# shared JAX rule implementations (SEMANTICS.md rules 6-8)
# ---------------------------------------------------------------------------

def queued_demand(s) -> jax.Array:
    waiting = (s.job_status == WAITING) & (s.job_subtime <= s.t)
    return jnp.sum(jnp.where(waiting, s.job_res, 0))


def timeout_switch_off(s, const, ipm_cap: bool):
    """Rule 6: switch off expired idle nodes, longest-idle first (ties by id).

    ``ipm_cap=True`` (PSAS+IPM) caps the count so available capacity never
    drops below queued demand.
    """
    cand = (
        (s.node_job < 0)
        & (s.node_state == IDLE)
        & (s.t - s.node_idle_since >= const.timeout)
    )
    n_cand = jnp.sum(cand, dtype=I32)
    if ipm_cap:
        avail = jnp.sum(
            (s.node_job < 0)
            & ((s.node_state == IDLE) | (s.node_state == SWITCHING_ON)),
            dtype=I32,
        )
        allowed = jnp.maximum(avail - queued_demand(s), 0)
    else:
        allowed = jnp.asarray(s.node_state.shape[0], I32)
    k = jnp.minimum(n_cand, allowed)
    key = jnp.where(cand, s.node_idle_since, INF)  # longest idle first
    order = jnp.argsort(key, stable=True)
    sel_sorted = jnp.arange(key.shape[0]) < k
    sel = jnp.zeros_like(cand).at[order].set(sel_sorted) & cand
    return s._replace(
        node_state=jnp.where(sel, SWITCHING_OFF, s.node_state),
        node_until=jnp.where(sel, s.t + const.t_off, s.node_until),
        n_switch_off=s.n_switch_off + jnp.sum(sel, dtype=I32),
    )


def ipm_wake(s, const):
    """Rule 7: wake sleeping nodes (lowest id first) to cover queued demand."""
    avail = jnp.sum(
        (s.node_job < 0)
        & ((s.node_state == IDLE) | (s.node_state == SWITCHING_ON)),
        dtype=I32,
    )
    deficit = queued_demand(s) - avail
    cand = (s.node_job < 0) & (s.node_state == SLEEP)
    sel = cand & (jnp.cumsum(cand) <= deficit)  # lowest id first
    return s._replace(
        node_state=jnp.where(sel, SWITCHING_ON, s.node_state),
        node_until=jnp.where(sel, s.t + const.t_on, s.node_until),
        n_switch_on=s.n_switch_on + jnp.sum(sel, dtype=I32),
    )


def _select_longest_idle(cand, idle_since, k):
    """Boolean mask of the k longest-idle candidates (ties by node id)."""
    key = jnp.where(cand, idle_since, INF)
    order = jnp.argsort(key, stable=True)
    k = jnp.minimum(jnp.sum(cand, dtype=I32), k)
    sel_sorted = jnp.arange(key.shape[0]) < k
    return jnp.zeros_like(cand).at[order].set(sel_sorted) & cand


def apply_rl_commands(s, const, grouped: bool = False):
    """Rule 8: apply pending RL power commands, then clear them.

    ``rl_on_cmd``/``rl_off_cmd`` are ``i32[G]`` per-group command vectors.

    * global mode (``grouped=False``): the effective counts are the vector
      sums; selection is cluster-wide (wake lowest-id sleeping, sleep
      longest-idle unreserved-idle) — bit-exact with the legacy scalar
      commands.
    * grouped mode: each group g wakes up to ``on[g]`` of *its* sleeping
      nodes (lowest id first) and sleeps up to ``off[g]`` of *its* unreserved
      idle nodes (longest idle first); groups are independent, so the
      expensive island can be slept while the cheap one is woken in one step.
    """
    cand_on = (s.node_job < 0) & (s.node_state == SLEEP)
    cand_off = (s.node_job < 0) & (s.node_state == IDLE)
    G = s.rl_on_cmd.shape[0]
    if grouped:
        same = const.group_id[None, :] == jnp.arange(G, dtype=I32)[:, None]
        ranks_on = jnp.cumsum(cand_on[None, :] & same, axis=1)  # [G, N]
        sel_on = cand_on & jnp.any(
            same & (ranks_on <= s.rl_on_cmd[:, None]), axis=0
        )
        sel_off_g = jax.vmap(_select_longest_idle, in_axes=(0, None, 0))(
            cand_off[None, :] & same, s.node_idle_since, s.rl_off_cmd
        )
        sel_off = jnp.any(sel_off_g, axis=0)
    else:
        sel_on = cand_on & (jnp.cumsum(cand_on) <= jnp.sum(s.rl_on_cmd))
        sel_off = _select_longest_idle(
            cand_off, s.node_idle_since, jnp.sum(s.rl_off_cmd)
        )
    state = jnp.where(sel_on, SWITCHING_ON, s.node_state)
    state = jnp.where(sel_off, SWITCHING_OFF, state)
    until = jnp.where(sel_on, s.t + const.t_on, s.node_until)
    until = jnp.where(sel_off, s.t + const.t_off, until)
    return s._replace(
        node_state=state,
        node_until=until,
        rl_on_cmd=jnp.zeros(G, I32),
        rl_off_cmd=jnp.zeros(G, I32),
        n_switch_on=s.n_switch_on + jnp.sum(sel_on, dtype=I32),
        n_switch_off=s.n_switch_off + jnp.sum(sel_off, dtype=I32),
    )


# ---------------------------------------------------------------------------
# the policy protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PowerPolicy:
    """Base protocol: a no-op power manager (never sleeps anything).

    Subclasses override the hooks below. All hooks are pure; the JAX set
    operates on ``engine.SimState``, the ``_ref`` set on a ``PyDES``
    instance — implement both for any new policy (SEMANTICS.md).
    """

    @property
    def eager_ready(self) -> bool:
        """True: scheduling treats every non-ACTIVE node as ready at t."""
        return True

    # ---- JAX engine hooks ----
    def post_schedule(self, s, const, cfg):
        return s

    def next_event_candidates(self, s, const, cfg) -> List[jax.Array]:
        return []

    # ---- sequential-oracle hooks ----
    def post_schedule_ref(self, des) -> None:
        return None

    def next_event_candidates_ref(self, des) -> List[float]:
        return []

    def psm_label(self) -> str:
        return "AlwaysOn"


@dataclasses.dataclass(frozen=True)
class AlwaysOn(PowerPolicy):
    """Classic always-on baseline: nodes never sleep (legacy PSM ``NONE``)."""


@dataclasses.dataclass(frozen=True)
class TimeoutSleep(PowerPolicy):
    """Idle-timeout switch-off (legacy PSUS / PSAS).

    ``transition_aware=False`` (PSUS): scheduling ignores power states — jobs
    simply wait for rule-5 wake-ups, keeping the O(N) allocation fast path.
    ``transition_aware=True`` (PSAS "Auto On"): ready times account for
    transition delays (the SEMANTICS.md variant table's right column).
    """

    transition_aware: bool = False

    @property
    def eager_ready(self) -> bool:
        return not self.transition_aware

    def post_schedule(self, s, const, cfg):
        return timeout_switch_off(s, const, ipm_cap=False)

    def next_event_candidates(self, s, const, cfg):
        if cfg.timeout is None:
            return []
        idle_unres = (s.node_job < 0) & (s.node_state == IDLE)
        expiry = s.node_idle_since + const.timeout
        return [jnp.min(jnp.where(idle_unres & (expiry > s.t), expiry, INF))]

    def post_schedule_ref(self, des):
        des._timeout_switch_off(ipm_cap=False)

    def next_event_candidates_ref(self, des):
        if des.cfg.timeout is None:
            return []
        return [
            nd.idle_since + des.cfg.timeout
            for nd in des.nodes
            if nd.job < 0 and nd.state == IDLE
        ]

    def psm_label(self) -> str:
        return "PSAS(AutoOn)" if self.transition_aware else "PSUS"


@dataclasses.dataclass(frozen=True)
class IPM(TimeoutSleep):
    """TimeoutSleep + intelligent power management (legacy PSAS+IPM):
    switch-offs are capped by queued demand and sleeping nodes are woken
    proactively when demand exceeds available capacity."""

    transition_aware: bool = True

    def post_schedule(self, s, const, cfg):
        s = timeout_switch_off(s, const, ipm_cap=True)
        return ipm_wake(s, const)

    def post_schedule_ref(self, des):
        des._timeout_switch_off(ipm_cap=True)
        des._ipm_wake()

    def psm_label(self) -> str:
        return "PSAS+IPM"


@dataclasses.dataclass(frozen=True)
class RLController(PowerPolicy):
    """Agent-controlled power commands (legacy PSM ``RL``).

    ``grouped=False``: commands are global counts (sum over the ``[G]``
    command vectors) — the checkpoint-compatible default. ``grouped=True``:
    commands target node groups individually (see ``apply_rl_commands``).

    ``controller``: optional in-graph policy ``f(s, const) -> (on[G], off[G])``
    evaluated inside ``post_schedule`` — this is how a checkpointed network
    drives ``run_sim`` end-to-end as one compiled program (``launch/sim.py``).
    When None, pending commands set externally (the RL env path) are applied.
    """

    grouped: bool = False
    controller: Optional[Callable] = None

    def post_schedule(self, s, const, cfg):
        if self.controller is not None:
            on, off = self.controller(s, const)
            s = s._replace(
                rl_on_cmd=jnp.broadcast_to(on, s.rl_on_cmd.shape).astype(I32),
                rl_off_cmd=jnp.broadcast_to(off, s.rl_off_cmd.shape).astype(I32),
            )
        return apply_rl_commands(s, const, grouped=self.grouped)

    def next_event_candidates(self, s, const, cfg):
        return [s.t + const.rl_interval]

    def post_schedule_ref(self, des):
        if des.rl_policy is not None:
            n_on, n_off = des.rl_policy(des)
            des._apply_rl(n_on, n_off)
            des._start_jobs()

    def next_event_candidates_ref(self, des):
        if des.cfg.rl_decision_interval:
            return [des.t + des.cfg.rl_decision_interval]
        return []

    def psm_label(self) -> str:
        return "RL:groups" if self.grouped else "RL"


# ---------------------------------------------------------------------------
# deprecation shim: PSMVariant <-> PowerPolicy
# ---------------------------------------------------------------------------

_PSM_TO_POLICY = {
    PSMVariant.NONE: AlwaysOn(),
    PSMVariant.PSUS: TimeoutSleep(),
    PSMVariant.PSAS: TimeoutSleep(transition_aware=True),
    PSMVariant.PSAS_IPM: IPM(),
    PSMVariant.RL: RLController(),
}


def policy_from_psm(psm: PSMVariant) -> PowerPolicy:
    """Legacy ``EngineConfig(psm=...)`` -> the equivalent policy stack."""
    return _PSM_TO_POLICY[PSMVariant(psm)]


def psm_of(policy: PowerPolicy) -> Optional[PSMVariant]:
    """Best-effort reverse map (None for policies with no legacy twin)."""
    if isinstance(policy, RLController):
        return PSMVariant.RL
    if isinstance(policy, IPM):
        return PSMVariant.PSAS_IPM
    if isinstance(policy, TimeoutSleep):
        return (
            PSMVariant.PSAS if policy.transition_aware else PSMVariant.PSUS
        )
    if isinstance(policy, AlwaysOn):
        return PSMVariant.NONE
    return None


# ---------------------------------------------------------------------------
# scheduler-label registry (single source of truth for launch/benchmarks)
# ---------------------------------------------------------------------------

_BASE_TOKENS = {"FCFS": BasePolicy.FCFS, "EASY": BasePolicy.EASY}
_PSM_TOKENS = {
    "PSUS": TimeoutSleep(),
    "PSAS": TimeoutSleep(transition_aware=True),
    "PSAS(AUTOON)": TimeoutSleep(transition_aware=True),  # alias
    "PSAS+IPM": IPM(),
    "ALWAYSON": AlwaysOn(),
    "RL": RLController(),
    "RL:GROUPS": RLController(grouped=True),
}
_CANONICAL_PSM = ("PSUS", "PSAS", "PSAS+IPM", "AlwaysOn")
_CANONICAL_RL = ("RL", "RL:groups")


def from_label(label: str) -> Tuple[BasePolicy, PowerPolicy]:
    """Parse ``"<FCFS|EASY> <PSM>"`` into a (base, policy) pair.

    PSM tokens: PSUS | PSAS | PSAS(AutoOn) | PSAS+IPM | AlwaysOn | RL |
    RL:groups (case-insensitive).
    """
    parts = label.split()
    if len(parts) == 2 and parts[0].upper() in _BASE_TOKENS:
        psm = _PSM_TOKENS.get(parts[1].upper())
        if psm is not None:
            return _BASE_TOKENS[parts[0].upper()], psm
    raise KeyError(
        f"unknown scheduler label {label!r}; expected one of "
        f"{', '.join(scheduler_labels(include_rl=True))} "
        f"(alias: 'PSAS(AutoOn)' for PSAS)"
    )


def scheduler_labels(include_rl: bool = False) -> Tuple[str, ...]:
    """Canonical labels, in the order the paper's figures use."""
    psms = _CANONICAL_PSM + (_CANONICAL_RL if include_rl else ())
    return tuple(
        f"{base} {psm}" for psm in psms for base in ("FCFS", "EASY")
    )


def label_of(base: BasePolicy, policy: PowerPolicy) -> str:
    b = "FCFS" if base == BasePolicy.FCFS else "EASY"
    p = policy.psm_label()
    return f"{b} {'PSAS' if p == 'PSAS(AutoOn)' else p}"
