"""Action translators (paper Config.py registry): discrete action -> node
power commands (n_on, n_off) applied per SEMANTICS.md rule 8."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import SimState
from repro.core.types import ACTIVE, IDLE, SWITCHING_ON


def delta_nodes(s: SimState, action, n_levels: int = 5, step_frac: float = 0.125):
    """Symmetric delta: action k in [0, 2*n_levels] -> toggle
    (k - n_levels) * step_frac * N nodes (negative = switch off)."""
    N = s.node_state.shape[0]
    step = jnp.maximum(jnp.int32(step_frac * N), 1)
    delta = jnp.clip((action.astype(jnp.int32) - n_levels) * step, -N, N)
    return jnp.maximum(delta, 0), jnp.maximum(-delta, 0)


def target_on_fraction(s: SimState, action, n_levels: int = 9):
    """action k -> target #powered nodes = round(N * k/(n_levels-1));
    commands bridge the gap from the current powered/powering count."""
    N = s.node_state.shape[0]
    target = jnp.round(
        N * action.astype(jnp.float32) / float(n_levels - 1)
    ).astype(jnp.int32)
    on_like = jnp.sum(
        (s.node_state == IDLE)
        | (s.node_state == ACTIVE)
        | (s.node_state == SWITCHING_ON),
        dtype=jnp.int32,
    )
    gap = target - on_like
    return jnp.maximum(gap, 0), jnp.maximum(-gap, 0)


ACTION_TRANSLATORS = {
    "delta": delta_nodes,
    "target_fraction": target_on_fraction,
}


def action_space_size(name: str, n_levels: int = None) -> int:
    if name == "delta":
        return 2 * (n_levels or 5) + 1
    if name == "target_fraction":
        return n_levels or 9
    raise KeyError(name)
