"""Action translators (paper Config.py registry): discrete action -> node
power commands applied per SEMANTICS.md rule 8 (and rule 9 for DVFS).

Every translator is ``f(sim_state, const, action, n_levels) -> (on, off)``
or ``-> (on, off, mode)`` where ``on``/``off`` are ``i32[G]`` per-group
command vectors (G = number of node groups, known from
``sim_state.rl_on_cmd``) and ``mode`` is an ``i32[G]`` DVFS mode-command
vector (-1 = leave the group's mode unchanged; rule 9). Use
:func:`full_commands` to normalize either arity to the triple. Global
translators put the whole command in one slot — the engine's global-action
mode reads the vector sums, so this is bit-compatible with the legacy
scalar commands. Group translators (``GROUP_ACTIONS``) emit genuinely
per-group commands and require an ``RLController(grouped=True)`` policy;
DVFS translators (``DVFS_ACTIONS``) emit mode commands and require an
``RLController(dvfs=True)`` policy.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import SimState
from repro.core.types import ACTIVE, IDLE, SWITCHING_ON

I32 = jnp.int32


def full_commands(s: SimState, ret):
    """Normalize a translator/controller return to ``(on, off, mode)``.

    Two-tuples (non-DVFS translators) get an all ``-1`` mode vector (no
    mode change, rule 9 no-op).
    """
    if len(ret) == 2:
        on, off = ret
        return on, off, jnp.full(s.rl_mode_cmd.shape[0], -1, I32)
    on, off, mode = ret
    return on, off, mode.astype(I32)


def _global(s: SimState, n_on, n_off):
    """Pack global scalar commands into the [G] command vectors (slot 0)."""
    G = s.rl_on_cmd.shape[0]
    zeros = jnp.zeros(G, I32)
    return zeros.at[0].set(n_on.astype(I32)), zeros.at[0].set(n_off.astype(I32))


def delta_nodes(s: SimState, const, action, n_levels: int = 5,
                step_frac: float = 0.125):
    """Symmetric delta: action k in [0, 2*n_levels] -> toggle
    (k - n_levels) * step_frac * N nodes (negative = switch off)."""
    N = s.node_state.shape[0]
    step = jnp.maximum(jnp.int32(step_frac * N), 1)
    delta = jnp.clip((action.astype(jnp.int32) - n_levels) * step, -N, N)
    return _global(s, jnp.maximum(delta, 0), jnp.maximum(-delta, 0))


def target_on_fraction(s: SimState, const, action, n_levels: int = 9):
    """action k -> target #powered nodes = round(N * k/(n_levels-1));
    commands bridge the gap from the current powered/powering count."""
    N = s.node_state.shape[0]
    target = jnp.round(
        N * action.astype(jnp.float32) / float(n_levels - 1)
    ).astype(jnp.int32)
    on_like = jnp.sum(
        (s.node_state == IDLE)
        | (s.node_state == ACTIVE)
        | (s.node_state == SWITCHING_ON),
        dtype=jnp.int32,
    )
    gap = target - on_like
    return _global(s, jnp.maximum(gap, 0), jnp.maximum(-gap, 0))


def group_target_fraction(s: SimState, const, action, n_levels: int = 9):
    """Group-targeted action space: action = g * n_levels + k sets group g's
    target powered-node count to round(N_g * k/(n_levels-1)); only that
    group receives commands this decision — the agent can sleep the
    expensive island while leaving the cheap one untouched."""
    G = s.rl_on_cmd.shape[0]
    g = (action.astype(I32) // n_levels).clip(0, G - 1)
    k = action.astype(I32) % n_levels
    gids = jnp.arange(G, dtype=I32)
    group_sizes = jnp.zeros(G, I32).at[const.group_id].add(1)
    on_like = (
        (s.node_state == IDLE)
        | (s.node_state == ACTIVE)
        | (s.node_state == SWITCHING_ON)
    )
    on_like_g = jnp.zeros(G, I32).at[const.group_id].add(on_like.astype(I32))
    target = jnp.round(
        group_sizes.astype(jnp.float32)
        * k.astype(jnp.float32)
        / float(n_levels - 1)
    ).astype(I32)
    gap = jnp.where(gids == g, target - on_like_g, 0)
    return jnp.maximum(gap, 0), jnp.maximum(-gap, 0)


def group_mode(s: SimState, const, action, n_levels: int):
    """DVFS action space (rule 9): action = g * n_levels + k commands group
    g's DVFS mode to k this decision; other groups keep their mode (-1).
    ``n_levels`` is the platform's mode-table width M
    (``PlatformSpec.n_dvfs_modes()``); out-of-table k is clamped per group
    by ``apply_dvfs``. Emits no on/off commands."""
    G = s.rl_on_cmd.shape[0]
    g = (action.astype(I32) // n_levels).clip(0, G - 1)
    k = action.astype(I32) % n_levels
    gids = jnp.arange(G, dtype=I32)
    mode = jnp.where(gids == g, k, -1).astype(I32)
    zeros = jnp.zeros(G, I32)
    return zeros, zeros, mode


ACTION_TRANSLATORS = {
    "delta": delta_nodes,
    "target_fraction": target_on_fraction,
    "group_target_fraction": group_target_fraction,
    "group_mode": group_mode,
}

# translators whose commands are per-group (need RLController(grouped=True))
GROUP_ACTIONS = frozenset({"group_target_fraction"})
# translators that command DVFS modes (need RLController(dvfs=True);
# n_levels must equal the platform's mode-table width)
DVFS_ACTIONS = frozenset({"group_mode"})


def action_space_size(name: str, n_levels: int = None, n_groups: int = 1) -> int:
    if name == "delta":
        return 2 * (n_levels or 5) + 1
    if name == "target_fraction":
        return n_levels or 9
    if name == "group_target_fraction":
        return n_groups * (n_levels or 9)
    if name == "group_mode":
        if not n_levels:
            raise ValueError(
                "group_mode needs n_levels = the platform's DVFS mode-table "
                "width (PlatformSpec.n_dvfs_modes())"
            )
        return n_groups * n_levels
    raise KeyError(name)
