"""Curriculum learning for the RL power manager (paper ref [7]: Budiarjo
et al., "Improving the efficiency of a DRL-based power management system for
HPC clusters using curriculum learning", SCA '25).

The idea from the reference: start the agent on forgiving workloads (sparse
arrivals — wrong power decisions cost little queueing) and progressively
increase pressure (denser arrivals, larger jobs) while keeping the policy
parameters across stages. Each stage is a standard A2C phase over freshly
generated workloads; only the environment distribution changes — the paper's
modular registry design means no engine/learner code is touched.

``default_curriculum`` scales the arrival density geometrically from
``ease_factor`` x the target inter-arrival down to the target; custom
stages are a list of (GeneratorConfig, n_updates).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax

from repro.core.engine import make_const
from repro.core.rl.a2c import (
    A2CConfig,
    TrainState,
    make_batched_sims,
    make_update_fn,
)
from repro.core.rl.env import EnvConfig, env_reset
from repro.core.rl.networks import policy_init
from repro.workloads.generator import GeneratorConfig, generate_workload
from repro.workloads.platform import PlatformSpec

Stage = Tuple[GeneratorConfig, int]  # (workload distribution, n_updates)


def default_curriculum(
    target: GeneratorConfig,
    n_stages: int = 3,
    updates_per_stage: int = 100,
    ease_factor: float = 4.0,
) -> List[Stage]:
    """Geometric arrival-density ramp ending at the target distribution."""
    stages: List[Stage] = []
    for i in range(n_stages):
        # stage 0 easiest (sparse), last stage == target
        f = ease_factor ** (1.0 - i / max(n_stages - 1, 1))
        cfg = dataclasses.replace(
            target,
            mean_interarrival=target.mean_interarrival * f,
            seed=target.seed + 1000 * i,
        )
        stages.append((cfg, updates_per_stage))
    return stages


def train_a2c_curriculum(
    platform: PlatformSpec,
    env_cfg: EnvConfig,
    stages: Sequence[Stage],
    cfg: A2CConfig = A2CConfig(),
    progress: Optional[Callable[[int, int, dict], None]] = None,
):
    """A2C across curriculum stages; policy params persist, optimizer state
    and environments reset per stage (fresh workload distribution).

    Returns (params, history) with ``history[i]['stage']`` marking stages.
    """
    const = make_const(platform, env_cfg.engine, specialize=True)
    key = jax.random.PRNGKey(cfg.seed)
    key, kp = jax.random.split(key)
    params = policy_init(kp, env_cfg.obs_size, env_cfg.n_actions, cfg.hidden)

    history = []
    for stage_idx, (gen_cfg, n_updates) in enumerate(stages):
        wls = [
            generate_workload(dataclasses.replace(gen_cfg, seed=gen_cfg.seed + s))
            for s in range(cfg.n_envs)
        ]
        sims0 = make_batched_sims(platform, wls, env_cfg)
        update, opt = make_update_fn(env_cfg, const, sims0, cfg)
        opt_state = opt.init(params)  # fresh optimizer stats per stage
        env_states, obs = jax.vmap(
            functools.partial(env_reset, env_cfg, const)
        )(sims0)
        key, ks = jax.random.split(key)
        ts = TrainState(params, opt_state, env_states, obs, ks)
        update_j = jax.jit(update)
        for i in range(n_updates):
            ts, metrics = update_j(ts)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["stage"] = stage_idx
            history.append(metrics)
            if progress:
                progress(stage_idx, i, metrics)
        params = ts.params  # carry the policy into the next stage
    return params, history
