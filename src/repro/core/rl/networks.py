"""Policy/value networks as raw-pytree JAX modules.

* ``mlp_*``     — the paper-scale agent (refs [7],[24] use small MLPs).
* ``policy_*``  — actor-critic wrapper with shared torso and two heads.

The transformer policy backbone for at-scale RL lives in
``repro.models`` (any assigned arch config can be used as a policy torso via
``repro.models.model.build_model``); these MLPs keep the paper-faithful agent
dependency-free.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32):
    params = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (fan_in, fan_out), dtype) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w, "b": jnp.zeros((fan_out,), dtype)})
    return params


def mlp_apply(params, x, final_activation=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params) or final_activation:
            x = jax.nn.tanh(x)
    return x


def policy_init(
    key, obs_size: int, n_actions: int, hidden: Sequence[int] = (128, 128)
):
    k1, k2, k3 = jax.random.split(key, 3)
    torso = mlp_init(k1, (obs_size, *hidden))
    pi_head = mlp_init(k2, (hidden[-1], n_actions))
    v_head = mlp_init(k3, (hidden[-1], 1))
    # zero-init heads: uniform initial policy, zero initial value
    pi_head[-1]["w"] = jnp.zeros_like(pi_head[-1]["w"])
    v_head[-1]["w"] = jnp.zeros_like(v_head[-1]["w"])
    return {"torso": torso, "pi": pi_head, "v": v_head}


def policy_apply(params, obs) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits[n_actions], value[])."""
    h = mlp_apply(params["torso"], obs, final_activation=True)
    logits = mlp_apply(params["pi"], h)
    value = mlp_apply(params["v"], h)[..., 0]
    return logits, value
