"""Feature extractors (the paper's Config.py registry, JAX-native).

Each extractor is ``f(sim_state, const) -> f32[feature_size]``, normalized to
roughly [0, 1] so a single MLP config works across platform sizes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import SimState, EngineConst, _queue_window
from repro.core.types import (
    ACTIVE,
    ALLOCATED,
    IDLE,
    RUNNING,
    SLEEP,
    SWITCHING_OFF,
    SWITCHING_ON,
    WAITING,
)

_TIME_SCALE = 3600.0  # an hour, for log-ish time normalization


def _t_norm(x):
    return jnp.log1p(jnp.maximum(x.astype(jnp.float32), 0.0) / _TIME_SCALE)


def _masked_mean(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    n = jnp.sum(mask, dtype=jnp.float32)
    return jnp.sum(jnp.where(mask, values, 0.0)) / jnp.maximum(n, 1.0)


def hetero_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """4-dim per-node power/speed summary (core/SEMANTICS.md §Heterogeneity).

    Tells the agent *which* nodes are currently idle/sleeping, not just how
    many: on a mixed platform sleeping the expensive-idle group first and
    waking the fast/cheap group first is the whole game. All terms are
    normalized by cluster-wide maxima, so they are exactly constant (0 spread)
    on homogeneous platforms and the same MLP config transfers.
    """
    key = const.order_key
    key_max = jnp.maximum(jnp.max(key), 1e-6)
    speed = const.speed
    speed_max = jnp.maximum(jnp.max(speed), 1e-6)
    idle = s.node_state == IDLE
    sleeping = s.node_state == SLEEP
    return jnp.stack(
        [
            # heterogeneity spread: 0 on homogeneous platforms
            (jnp.max(key) - jnp.min(key)) / key_max,
            # how expensive the currently-idle pool is (sleep these first)
            _masked_mean(key / key_max, idle),
            # how fast the currently-sleeping pool is (wake these first)
            _masked_mean(speed / speed_max, sleeping),
            # how fast the currently-idle pool is
            _masked_mean(speed / speed_max, idle),
        ]
    )


def compact_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """20-dim summary: node-state mix, queue pressure, head-job profile,
    per-node power/speed heterogeneity summary.

    Mirrors the observation designs of the paper's refs [7],[24]
    (state-mix + queue statistics), adapted to fixed-width vector form.
    """
    N = s.node_state.shape[0]
    fN = jnp.float32(N)
    state_frac = [
        jnp.sum(s.node_state == k, dtype=jnp.float32) / fN
        for k in (SLEEP, SWITCHING_ON, IDLE, ACTIVE, SWITCHING_OFF)
    ]
    reserved_frac = jnp.sum(s.node_job >= 0, dtype=jnp.float32) / fN

    arrived_waiting = (s.job_status == WAITING) & (s.job_subtime <= s.t)
    qlen = jnp.sum(arrived_waiting, dtype=jnp.float32)
    qdemand = jnp.sum(jnp.where(arrived_waiting, s.job_res, 0), dtype=jnp.float32)
    alloc_cnt = jnp.sum(s.job_status == ALLOCATED, dtype=jnp.float32)
    running = jnp.sum(s.job_status == RUNNING, dtype=jnp.float32)

    window = _queue_window(s, 1)
    head = jnp.maximum(window[0], 0)
    head_valid = (window[0] >= 0).astype(jnp.float32)
    head_res = s.job_res[head].astype(jnp.float32) / fN * head_valid
    head_wait = _t_norm(s.t - s.job_subtime[head]) * head_valid
    head_req = _t_norm(s.job_reqtime[head]) * head_valid

    # next arrival proximity (anticipation signal for proactive wake-up)
    future = (s.job_status == WAITING) & (s.job_subtime > s.t)
    next_arr = jnp.min(jnp.where(future, s.job_subtime, s.t + jnp.int32(2**29)))
    next_arr_gap = _t_norm(next_arr - s.t)

    remaining = jnp.sum(s.job_exists & (s.job_status != 3), dtype=jnp.float32)
    total = jnp.maximum(jnp.sum(s.job_exists, dtype=jnp.float32), 1.0)

    base = jnp.stack(
        state_frac
        + [
            reserved_frac,
            jnp.minimum(qlen / 32.0, 4.0),
            jnp.minimum(qdemand / fN, 4.0),
            alloc_cnt / 32.0,
            running / fN * 4.0,
            head_valid,
            head_res,
            head_wait,
            head_req,
            next_arr_gap,
            remaining / total,
        ]
    )
    return jnp.concatenate([base, hetero_features(s, const)])


def queue_window_features(s: SimState, const: EngineConst, W: int = 8) -> jnp.ndarray:
    """compact_features + per-job features of the first W queued jobs
    (token-style observation for the transformer policy)."""
    base = compact_features(s, const)
    N = s.node_state.shape[0]
    window = _queue_window(s, W)
    valid = (window >= 0).astype(jnp.float32)
    idx = jnp.maximum(window, 0)
    res = s.job_res[idx].astype(jnp.float32) / jnp.float32(N) * valid
    wait = _t_norm(s.t - s.job_subtime[idx]) * valid
    req = _t_norm(s.job_reqtime[idx]) * valid
    per_job = jnp.stack([valid, res, wait, req], axis=-1).reshape(-1)
    return jnp.concatenate([base, per_job])


def group_mix_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """Per-group summary, ``f32[G * 6]`` (G = number of node groups, known
    statically from the [G, 5] energy ledger shape).

    For the group-targeted action space the agent needs to see *each
    island's* state mix, not just the cluster totals: 5 within-group state
    fractions plus the group's order-key share of the cluster maximum (which
    island is expensive). All terms are in [0, 1].
    """
    G = s.energy.shape[0]
    sizes = jnp.zeros(G, jnp.int32).at[const.group_id].add(1)
    fsizes = jnp.maximum(sizes.astype(jnp.float32), 1.0)
    fracs = [
        jnp.zeros(G, jnp.float32)
        .at[const.group_id]
        .add((s.node_state == k).astype(jnp.float32))
        / fsizes
        for k in (SLEEP, SWITCHING_ON, IDLE, ACTIVE, SWITCHING_OFF)
    ]
    key_max = jnp.maximum(jnp.max(const.order_key), 1e-6)
    key_g = (
        jnp.zeros(G, jnp.float32).at[const.group_id].add(const.order_key)
        / fsizes
        / key_max
    )
    return jnp.stack(fracs + [key_g], axis=-1).reshape(-1)


def compact_group_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """compact_features + the per-group state-mix block (the observation for
    group-targeted RL actions)."""
    return jnp.concatenate([compact_features(s, const), group_mix_features(s, const)])


def dvfs_mode_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """Per-group current-DVFS-mode summary, ``f32[G * 3]`` (§DVFS).

    Normalized mode index (0 = slowest .. 1 = fastest of the group's table),
    current mode speed / cluster max table speed, and current mode watts /
    cluster max table watts — enough for the agent to see where each island
    sits on its energy/speed trade-off. All terms in [0, 1]; exactly
    constant when no DVFS table is declared (single-mode platforms).
    """
    G = const.dvfs_speed.shape[0]
    gids = jnp.arange(G)
    span = jnp.maximum(const.dvfs_n_modes.astype(jnp.float32) - 1.0, 1.0)
    idx = s.dvfs_mode.astype(jnp.float32) / span
    sp = const.dvfs_speed[gids, s.dvfs_mode] / jnp.maximum(
        jnp.max(const.dvfs_speed), 1e-6
    )
    wt = const.dvfs_watts[gids, s.dvfs_mode] / jnp.maximum(
        jnp.max(const.dvfs_watts), 1e-6
    )
    return jnp.stack([idx, sp, wt], axis=-1).reshape(-1)


def compact_dvfs_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """compact_group_features + the DVFS mode block (the observation for
    RL-commanded DVFS: the agent needs both each island's state mix and its
    current operating point)."""
    return jnp.concatenate(
        [compact_group_features(s, const), dvfs_mode_features(s, const)]
    )


def forecast_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """EWMA-predictor summary, ``f32[4]`` (core/SEMANTICS.md §Forecast).

    Exposes rule 10's predictor state to the agent: smoothed inter-arrival
    gap (log-hour normalized; the INF_TIME init reads as "never seen an
    arrival"), smoothed per-arrival resource ask / N, the current predicted
    extra-node pressure / N, and the configured horizon (log-hour). All
    terms stay at their init-value constants when no Forecast policy runs,
    so the block is harmless to stack onto non-forecast observations.
    """
    from repro.core.policy import forecast_pressure

    N = s.node_state.shape[0]
    fN = jnp.float32(N)
    return jnp.stack(
        [
            _t_norm(s.fc_gap),
            jnp.minimum(s.fc_res / fN, 4.0),
            forecast_pressure(s, const).astype(jnp.float32) / fN,
            _t_norm(const.forecast_horizon),
        ]
    )


def compact_forecast_features(s: SimState, const: EngineConst) -> jnp.ndarray:
    """compact_features + the forecast-predictor block (the observation for
    RL stacks composed with rule 10: the agent sees the same arrival
    pressure the proactive wake acts on)."""
    return jnp.concatenate(
        [compact_features(s, const), forecast_features(s, const)]
    )


FEATURE_EXTRACTORS = {
    "compact": compact_features,
    "queue_window": queue_window_features,
    "compact_groups": compact_group_features,
    "compact_dvfs": compact_dvfs_features,
    "compact_forecast": compact_forecast_features,
}


def feature_size(name: str, window: int = 8, n_groups: int = 1) -> int:
    if name == "compact":
        return 20
    if name == "queue_window":
        return 20 + 4 * window
    if name == "compact_groups":
        return 20 + 6 * n_groups
    if name == "compact_dvfs":
        return 20 + 9 * n_groups
    if name == "compact_forecast":
        return 20 + 4
    raise KeyError(name)
