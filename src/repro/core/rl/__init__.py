"""RL extension of the simulator (paper §2: HPCGymEnv + Config.py registries).

The paper wraps its simulator in a Gym env and registers feature extractors,
action translators, rewards and learners. Here the env is a pair of pure
functions (``env_reset`` / ``env_step``) over :class:`EnvState`, so the whole
agent-environment loop jits and vmaps: thousands of simulated HPC clusters
step in lockstep, sharded over the mesh ``data`` axis.
"""
from repro.core.rl.env import EnvConfig, EnvState, HPCGymEnv, env_reset, env_step
from repro.core.rl.features import FEATURE_EXTRACTORS, feature_size
from repro.core.rl.actions import ACTION_TRANSLATORS, action_space_size
from repro.core.rl.rewards import REWARDS
from repro.core.rl.networks import mlp_init, mlp_apply, policy_init, policy_apply
from repro.core.rl.a2c import A2CConfig, train_a2c
from repro.core.rl.ppo import PPOConfig, train_ppo

__all__ = [
    "EnvConfig",
    "EnvState",
    "HPCGymEnv",
    "env_reset",
    "env_step",
    "FEATURE_EXTRACTORS",
    "feature_size",
    "ACTION_TRANSLATORS",
    "action_space_size",
    "REWARDS",
    "mlp_init",
    "mlp_apply",
    "policy_init",
    "policy_apply",
    "A2CConfig",
    "train_a2c",
    "PPOConfig",
    "train_ppo",
]
