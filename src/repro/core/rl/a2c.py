"""A2C learner (the paper's ref [24] trains A2C power managers; ref [7] adds
curriculum learning — see ``examples/train_rl_power_manager.py``).

The rollout is a ``lax.scan`` over vmapped env steps, so one update =
one XLA program; environments auto-reset. ``make_update_fn`` returns a jitted
(or pjit-sharded) update usable both on CPU for the paper-scale agent and on
the production mesh (env batch sharded over ``("pod","data")``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConst, SimState, init_state, make_const
from repro.core.rl.env import EnvConfig, EnvState, env_reset, env_step
from repro.core.rl.networks import policy_apply, policy_init
from repro.training.optimizer import adamw, apply_updates, clip_by_global_norm
from repro.workloads.platform import PlatformSpec
from repro.workloads.workload import Workload


@dataclasses.dataclass(frozen=True)
class A2CConfig:
    n_envs: int = 32
    n_steps: int = 16
    gamma: float = 0.99
    gae_lambda: float = 0.95
    lr: float = 3e-4
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    max_grad_norm: float = 0.5
    n_updates: int = 200
    hidden: Tuple[int, ...] = (128, 128)
    seed: int = 0


class Rollout(NamedTuple):
    obs: jax.Array  # [T, B, obs]
    actions: jax.Array  # [T, B]
    rewards: jax.Array  # [T, B]
    dones: jax.Array  # [T, B] done AFTER the step
    values: jax.Array  # [T, B] value at obs
    last_value: jax.Array  # [B]
    live: jax.Array  # [T, B] env was live when acting


def make_batched_sims(
    platform: PlatformSpec,
    workloads: Sequence[Workload],
    env_cfg: EnvConfig,
    job_capacity: Optional[int] = None,
) -> SimState:
    cap = job_capacity or max(len(w) for w in workloads)
    sims = [
        init_state(platform, w, env_cfg.engine, job_capacity=cap)
        for w in workloads
    ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sims)


def collect_rollout(
    params,
    env_states: EnvState,
    obs: jax.Array,
    key: jax.Array,
    sims0: SimState,
    env_cfg: EnvConfig,
    const: EngineConst,
    n_steps: int,
) -> Tuple[EnvState, jax.Array, jax.Array, Rollout]:
    """T steps of the vmapped env with auto-reset; returns data for the loss."""
    reset_fn = jax.vmap(functools.partial(env_reset, env_cfg, const))
    step_fn = jax.vmap(functools.partial(env_step, env_cfg, const))

    def one_step(carry, _):
        env_states, obs, key = carry
        # auto-reset envs that finished on the previous step
        fresh_states, fresh_obs = reset_fn(sims0)
        need_reset = env_states.done
        env_states = jax.tree_util.tree_map(
            lambda f, c: jnp.where(
                need_reset.reshape((-1,) + (1,) * (c.ndim - 1)), f, c
            ),
            fresh_states,
            env_states,
        )
        obs = jnp.where(need_reset[:, None], fresh_obs, obs)

        logits, value = jax.vmap(policy_apply, (None, 0))(params, obs)
        key, k = jax.random.split(key)
        action = jax.random.categorical(k, logits)
        live = ~env_states.done
        env_states, next_obs, reward, done, _ = step_fn(env_states, action)
        out = (obs, action, reward, done, value, live)
        return (env_states, next_obs, key), out

    (env_states, obs, key), (obs_t, act_t, rew_t, done_t, val_t, live_t) = (
        jax.lax.scan(one_step, (env_states, obs, key), None, length=n_steps)
    )
    _, last_value = jax.vmap(policy_apply, (None, 0))(params, obs)
    roll = Rollout(obs_t, act_t, rew_t, done_t, val_t, last_value, live_t)
    return env_states, obs, key, roll


def gae(roll: Rollout, gamma: float, lam: float) -> Tuple[jax.Array, jax.Array]:
    """Generalized advantage estimation over the [T, B] rollout."""

    def back(carry, x):
        adv_next, v_next = carry
        reward, done, value = x
        nonterm = 1.0 - done.astype(jnp.float32)
        delta = reward + gamma * v_next * nonterm - value
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, value), adv

    (_, _), advs = jax.lax.scan(
        back,
        (jnp.zeros_like(roll.last_value), roll.last_value),
        (roll.rewards, roll.dones, roll.values),
        reverse=True,
    )
    returns = advs + roll.values
    return advs, returns


def a2c_loss(params, roll: Rollout, advs, returns, cfg: A2CConfig):
    logits, values = jax.vmap(jax.vmap(policy_apply, (None, 0)), (None, 0))(
        params, roll.obs
    )
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, roll.actions[..., None], axis=-1)[..., 0]
    mask = roll.live.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    adv_n = (advs - jnp.sum(advs * mask) / n) / (
        jnp.sqrt(jnp.sum(jnp.square(advs) * mask) / n) + 1e-6
    )
    pg = -jnp.sum(logp * jax.lax.stop_gradient(adv_n) * mask) / n
    vf = jnp.sum(jnp.square(values - returns) * mask) / n
    ent = -jnp.sum(jnp.sum(jnp.exp(logp_all) * logp_all, -1) * mask) / n
    loss = pg + cfg.vf_coef * vf - cfg.ent_coef * ent
    return loss, {"pg_loss": pg, "vf_loss": vf, "entropy": ent}


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    env_states: EnvState
    obs: jax.Array
    key: jax.Array


def make_update_fn(
    env_cfg: EnvConfig,
    const: EngineConst,
    sims0: SimState,
    cfg: A2CConfig,
    optimizer=None,
    devices=None,
) -> Callable[[TrainState], Tuple[TrainState, dict]]:
    """The jittable A2C update. ``devices`` (core/SEMANTICS.md
    §Device-sharded sweeps, RL layer) shards the env batch across a 1-D
    local-device mesh: each device rolls out its ``n_envs / D`` slice
    (data-parallel) and the gradient is psum-reduced across the mesh
    before the (replicated) optimizer step — the classic DDP shape, so
    params stay bit-identical on every device."""
    opt = optimizer or adamw(lr=cfg.lr)
    D = _resolve_rollout_devices(devices, env_cfg, cfg.n_envs)

    def update(ts: TrainState, sims) -> Tuple[TrainState, dict]:
        if D is None:
            key_roll = ts.key
        else:
            # per-shard RNG: fold the mesh position into a split of the
            # replicated key, so shards explore independently while the
            # carried TrainState.key stays replicated
            key_roll = jax.random.fold_in(
                jax.random.split(ts.key)[1], jax.lax.axis_index("env")
            )
        env_states, obs, key, roll = collect_rollout(
            ts.params, ts.env_states, ts.obs, key_roll, sims, env_cfg,
            const, cfg.n_steps,
        )
        advs, returns = gae(roll, cfg.gamma, cfg.gae_lambda)
        (loss, aux), grads = jax.value_and_grad(a2c_loss, has_aux=True)(
            ts.params, roll, advs, returns, cfg
        )
        if D is not None:
            # psum/D gradient reduction: the update consumes the mean of
            # the per-shard gradients (identical on every device)
            grads = jax.lax.pmean(grads, "env")
            key = jax.random.split(ts.key)[0]  # replicated successor
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
        updates, opt_state = opt.update(grads, ts.opt_state, ts.params)
        params = apply_updates(ts.params, updates)
        mask = roll.live.astype(jnp.float32)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "mean_reward": jnp.sum(roll.rewards * mask)
            / jnp.maximum(jnp.sum(mask), 1.0),
            **aux,
        }
        if D is not None:
            metrics = {k: jax.lax.pmean(v, "env") for k, v in metrics.items()}
        return TrainState(params, opt_state, env_states, obs, key), metrics

    return _maybe_shard_update(update, sims0, D), opt


def _resolve_rollout_devices(devices, env_cfg: EnvConfig, n_envs: int):
    """Resolve the rollout device count (None = unsharded; falls back to
    ``env_cfg.engine.devices``) and validate the batch divides across it."""
    from repro.core.engine import _resolve_devices

    D = _resolve_devices(devices, env_cfg.engine)
    if D is None or D == 1:
        return None
    if n_envs % D:
        raise ValueError(
            f"n_envs={n_envs} does not shard evenly across {D} devices; "
            "size the env batch to a device multiple"
        )
    return D


def _maybe_shard_update(update, sims0: SimState, D) -> Callable:
    """Close the reset pool into the update; with a device count, lower it
    through ``shard_map`` on the 1-D ``("env",)`` mesh: params/opt
    state/key replicated, env batch (and the reset pool) sharded."""
    if D is None:
        return lambda ts: update(ts, sims0)
    from jax.experimental.shard_map import shard_map

    from repro.core.rl.env import rollout_mesh

    P = jax.sharding.PartitionSpec
    ts_spec = TrainState(
        params=P(), opt_state=P(), env_states=P("env"), obs=P("env"), key=P()
    )
    sharded = shard_map(
        update,
        mesh=rollout_mesh(D),
        in_specs=(ts_spec, P("env")),
        out_specs=(ts_spec, P()),
        check_rep=False,
    )
    return lambda ts: sharded(ts, sims0)


def train_a2c(
    platform: PlatformSpec,
    workloads: Sequence[Workload],
    env_cfg: EnvConfig,
    cfg: A2CConfig = A2CConfig(),
    progress: Optional[Callable[[int, dict], None]] = None,
    devices=None,
):
    """Paper-scale A2C training loop (single host). Returns (params, history).

    ``devices`` shards the ``n_envs`` rollout batch across local devices
    (data-parallel + psum'd gradients — §Device-sharded sweeps, RL layer);
    ``None`` falls back to ``env_cfg.engine.devices``, unsharded when that
    is None too."""
    from repro.core.rl.env import shard_env_batch

    # closure constant of the jitted update: specialize the policy flags so
    # every rollout step traces only the RL stack's rules
    const = make_const(platform, env_cfg.engine, specialize=True)
    wls = list(workloads)
    if len(wls) < cfg.n_envs:
        wls = (wls * ((cfg.n_envs + len(wls) - 1) // len(wls)))[: cfg.n_envs]
    sims0 = make_batched_sims(platform, wls[: cfg.n_envs], env_cfg)
    sims0 = shard_env_batch(sims0, devices, env_cfg.engine)

    key = jax.random.PRNGKey(cfg.seed)
    key, kp = jax.random.split(key)
    params = policy_init(kp, env_cfg.obs_size, env_cfg.n_actions, cfg.hidden)
    update, opt = make_update_fn(env_cfg, const, sims0, cfg, devices=devices)
    opt_state = opt.init(params)

    env_states, obs = jax.vmap(functools.partial(env_reset, env_cfg, const))(sims0)
    ts = TrainState(params, opt_state, env_states, obs, key)

    update_j = jax.jit(update)
    history = []
    for i in range(cfg.n_updates):
        ts, metrics = update_j(ts)
        metrics = {k: float(v) for k, v in metrics.items()}
        history.append(metrics)
        if progress:
            progress(i, metrics)
    return ts.params, history
