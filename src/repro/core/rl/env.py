"""Gym-style environment over the vectorized simulator (paper's HPCGymEnv).

``env_reset`` / ``env_step`` are pure, so the full agent-environment loop
jits, vmaps over environment batches, and shards over the mesh ``data`` axis.
The decision cadence follows the paper: the agent acts at every simulation
event (plus an optional periodic tick via ``rl_decision_interval``).

:class:`HPCGymEnv` is a thin host-side wrapper exposing the classic
``reset()/step(action)`` protocol for single-environment experimentation
(gym/gymnasium API shape, without requiring the dependency).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import (
    EngineConst,
    SimState,
    accrue_energy,
    all_done,
    event_horizon,
    init_state,
    make_const,
    next_time,
    process_batch,
    trim_window,
)
from repro.core.policy import RLController, apply_dvfs, apply_rl_commands
from repro.core.rl.actions import (
    ACTION_TRANSLATORS,
    DVFS_ACTIONS,
    GROUP_ACTIONS,
    action_space_size,
    full_commands,
)
from repro.core.rl.features import FEATURE_EXTRACTORS, feature_size
from repro.core.rl.rewards import REWARDS, RewardWeights
from repro.core.types import INF_TIME, EngineConfig
from repro.workloads.platform import PlatformSpec
from repro.workloads.workload import Workload

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    engine: EngineConfig = dataclasses.field(
        default_factory=lambda: EngineConfig(policy=RLController())
    )
    feature: str = "compact"
    action: str = "target_fraction"
    n_action_levels: int = 9
    reward: str = "waste_wait"
    reward_weights: RewardWeights = dataclasses.field(default_factory=RewardWeights)
    max_steps: int = 512
    feature_window: int = 8
    # node-group count of the platform (group-targeted actions / features
    # need it to size the action space and observation statically)
    n_groups: int = 1

    def __post_init__(self):
        if not isinstance(self.engine.policy, RLController):
            raise ValueError(
                "EnvConfig.engine must use an RLController policy "
                "(legacy spelling: EngineConfig(psm=PSMVariant.RL))"
            )
        if self.engine.policy.controller is not None:
            raise ValueError(
                "EnvConfig.engine.policy.controller must be None: the env "
                "supplies the actions (in-graph controllers are for "
                "run_sim/launch runs)"
            )
        if (self.action in GROUP_ACTIONS) != self.engine.policy.grouped:
            raise ValueError(
                f"action {self.action!r} and RLController(grouped="
                f"{self.engine.policy.grouped}) disagree: group-targeted "
                "actions need a grouped controller and vice versa"
            )
        if (self.action in DVFS_ACTIONS) != self.engine.policy.dvfs:
            raise ValueError(
                f"action {self.action!r} and RLController(dvfs="
                f"{self.engine.policy.dvfs}) disagree: DVFS mode commands "
                "need a dvfs controller (rule 9) and vice versa"
            )

    @property
    def n_actions(self) -> int:
        return action_space_size(self.action, self.n_action_levels, self.n_groups)

    @property
    def obs_size(self) -> int:
        return feature_size(self.feature, self.feature_window, self.n_groups)


class EnvState(NamedTuple):
    sim: SimState
    steps: jax.Array  # i32 decision steps taken
    done: jax.Array  # bool


def _features(cfg: EnvConfig, sim: SimState, const: EngineConst) -> jax.Array:
    fn = FEATURE_EXTRACTORS[cfg.feature]
    if cfg.feature == "queue_window":
        return fn(sim, const, cfg.feature_window)
    return fn(sim, const)


def env_reset(
    cfg: EnvConfig, const: EngineConst, sim0: SimState
) -> Tuple[EnvState, jax.Array]:
    """Initialize an episode: process the t=0 batch, return first observation."""
    sim = process_batch(sim0, const, cfg.engine)
    state = EnvState(sim=sim, steps=jnp.asarray(0, I32), done=all_done(sim))
    return state, _features(cfg, sim, const)


def env_step(
    cfg: EnvConfig, const: EngineConst, state: EnvState, action: jax.Array
) -> Tuple[EnvState, jax.Array, jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Apply the agent's power command at the current time, then advance one
    event batch. Returns (state, obs, reward, done, info). No-op when done."""
    prev = state.sim

    n_on, n_off, n_mode = full_commands(
        prev,
        ACTION_TRANSLATORS[cfg.action](prev, const, action, cfg.n_action_levels),
    )
    sim = prev._replace(rl_on_cmd=n_on, rl_off_cmd=n_off, rl_mode_cmd=n_mode)
    sim = apply_rl_commands(sim, const, grouped=cfg.engine.policy.grouped)
    if cfg.engine.policy.dvfs:  # rule 9: apply the agent's mode commands now
        sim = apply_dvfs(
            sim, const,
            terminate_overrun=cfg.engine.terminate_overrun, rl=True,
        )

    # fused event pass (core/SEMANTICS.md §Hot loop): one read of the node
    # arrays yields the next-event time and the draw the accrual reuses
    if cfg.engine.fused_events:
        nt, aux = event_horizon(sim, const, cfg.engine)
    else:
        nt, aux = next_time(sim, const, cfg.engine), None
    can_advance = (nt < INF_TIME) & ~all_done(sim)
    sim_adv = accrue_energy(
        sim, jnp.where(can_advance, nt, sim.t), const, aux=aux
    )
    sim_adv = sim_adv._replace(t=jnp.where(can_advance, nt, sim.t))
    sim_adv = process_batch(sim_adv, const, cfg.engine)
    sim = jax.tree_util.tree_map(
        lambda a, b: jnp.where(state.done, a, b), state.sim, sim_adv
    )

    steps = state.steps + jnp.where(state.done, 0, 1)
    done = state.done | all_done(sim) | ~can_advance | (steps >= cfg.max_steps)
    reward = jnp.where(
        state.done,
        0.0,
        REWARDS[cfg.reward](prev, sim, const, cfg.reward_weights),
    )
    obs = _features(cfg, sim, const)
    info = {
        "t": sim.t,
        "energy_j": jnp.sum(sim.energy),
        "wait_integral": sim.wait_integral,
    }
    return EnvState(sim, steps, done), obs, reward, done, info


def rollout_mesh(D: int) -> "jax.sharding.Mesh":
    """The 1-D device mesh the RL layer shards its env batch over — the
    same mesh shape ``engine.sweep`` lowers sweep scenarios onto
    (core/SEMANTICS.md §Device-sharded sweeps), named ``"env"`` here."""
    import numpy as np

    return jax.sharding.Mesh(np.asarray(jax.devices()[:D]), ("env",))


def shard_env_batch(tree, devices=None, engine_cfg: Optional[EngineConfig] = None):
    """Place a stacked env batch (every leaf's leading axis = B) on a 1-D
    device mesh (§Device-sharded sweeps, RL layer).

    ``devices`` follows ``engine.sweep``'s contract — ``None`` (fall back
    to ``engine_cfg.devices``; unsharded when that is None too), an int
    ``D``, or ``"all"``. B must divide by the device count: env batches
    are caller-sized (``n_envs``), so no pad/mask machinery here. The
    placement is semantics-free — the jitted vmapped step partitions
    elementwise over the batch, so sharded rollouts step the exact same
    per-env programs, just D at a time.
    """
    from repro.core.engine import _resolve_devices

    D = _resolve_devices(devices, engine_cfg or EngineConfig())
    if D is None or D == 1:
        return tree
    B = jax.tree_util.tree_leaves(tree)[0].shape[0]
    if B % D:
        raise ValueError(
            f"env batch of {B} does not shard evenly across {D} devices; "
            "size n_envs to a device multiple"
        )
    sharding = jax.sharding.NamedSharding(
        rollout_mesh(D), jax.sharding.PartitionSpec("env")
    )
    return jax.tree_util.tree_map(
        lambda a: jax.device_put(a, sharding), tree
    )


def batched_reset(cfg: EnvConfig, const: EngineConst, sims0: SimState):
    """vmapped reset over a batch of initial sim states (leading axis B)."""
    return jax.vmap(functools.partial(env_reset, cfg, const))(sims0)


def batched_step(cfg: EnvConfig, const: EngineConst, states: EnvState, actions):
    return jax.vmap(functools.partial(env_step, cfg, const))(states, actions)


class HPCGymEnv:
    """Host-side gym-like wrapper (single environment, eager stepping)."""

    def __init__(
        self,
        platform: PlatformSpec,
        workload: Workload,
        config: Optional[EnvConfig] = None,
        job_capacity: Optional[int] = None,
    ):
        self.cfg = config or EnvConfig()
        needs_groups = (
            self.cfg.action in GROUP_ACTIONS
            or self.cfg.action in DVFS_ACTIONS
            or self.cfg.feature in ("compact_groups", "compact_dvfs")
        )
        if needs_groups and self.cfg.n_groups != platform.n_groups():
            raise ValueError(
                f"EnvConfig.n_groups={self.cfg.n_groups} but the platform "
                f"has {platform.n_groups()} node groups; group-targeted "
                "actions/features size the action space and observation "
                "from n_groups"
            )
        if (
            self.cfg.action in DVFS_ACTIONS
            and self.cfg.n_action_levels != platform.n_dvfs_modes()
        ):
            raise ValueError(
                f"EnvConfig.n_action_levels={self.cfg.n_action_levels} but "
                f"the platform's DVFS mode-table width is "
                f"{platform.n_dvfs_modes()}; mode commands would be "
                "mis-decoded (set n_action_levels = n_dvfs_modes())"
            )
        self.platform = platform
        self.workload = workload
        # workload-derived window trim (§Hot loop): the queue can never
        # exceed the job count, so the scheduler scan stops paying for
        # slots the workload cannot fill — bit-exact
        self.cfg = dataclasses.replace(
            self.cfg, engine=trim_window(self.cfg.engine, len(workload))
        )
        # the env's const is a closure constant of the jitted reset/step
        # (functools.partial below), so the policy flags specialize: the
        # rollout traces only the RLController rules (§Static specialization)
        self.const = make_const(platform, self.cfg.engine, specialize=True)
        self._sim0 = init_state(
            platform, workload, self.cfg.engine, job_capacity=job_capacity
        )
        self._reset = jax.jit(functools.partial(env_reset, self.cfg, self.const))
        self._step = jax.jit(functools.partial(env_step, self.cfg, self.const))
        self.state: Optional[EnvState] = None

    @property
    def action_space_n(self) -> int:
        return self.cfg.n_actions

    @property
    def observation_size(self) -> int:
        return self.cfg.obs_size

    def reset(self) -> Any:
        self.state, obs = self._reset(self._sim0)
        return obs

    def step(self, action) -> Tuple[Any, float, bool, Dict]:
        self.state, obs, reward, done, info = self._step(
            self.state, jnp.asarray(action, I32)
        )
        return obs, float(reward), bool(done), {k: v for k, v in info.items()}
