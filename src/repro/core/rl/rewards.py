"""Reward functions (paper Config.py registry).

Each reward is ``r(prev_sim, new_sim, const, weights) -> f32`` computed from
accounting deltas between decision points — the energy-waste / waiting-time
trade-off the paper centers on (refs [7],[24] use the same two terms).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.engine import SimState, EngineConst
from repro.core.types import IDLE, SWITCHING_OFF, SWITCHING_ON


@dataclasses.dataclass(frozen=True)
class RewardWeights:
    w_energy: float = 1.0
    w_wait: float = 1.0


def _waste_j(s: SimState) -> jnp.ndarray:
    # energy ledger is [G, 5]; sum the waste states over node groups
    return (
        jnp.sum(s.energy[..., IDLE])
        + jnp.sum(s.energy[..., SWITCHING_ON])
        + jnp.sum(s.energy[..., SWITCHING_OFF])
    )


def _cluster_active_watts(const: EngineConst) -> jnp.ndarray:
    """Full-cluster active draw (W) — per-node on heterogeneous platforms."""
    return jnp.sum(const.power[..., 3])


def waste_wait_tradeoff(
    prev: SimState, new: SimState, const: EngineConst, w: RewardWeights
) -> jnp.ndarray:
    """r = -(w_e * Δwasted_energy + w_w * Δaggregate_wait), normalized.

    Energy normalized by full-cluster active draw per hour; waiting by
    node-hours, so both terms are O(1) per simulated hour and the weights
    express the operator's actual trade-off preference.
    """
    N = new.node_state.shape[0]
    e_scale = _cluster_active_watts(const) * 3600.0  # J per cluster-hour
    w_scale = jnp.float32(N) * 3600.0  # node-seconds per cluster-hour
    d_waste = (_waste_j(new) - _waste_j(prev)) / e_scale
    d_wait = (new.wait_integral - prev.wait_integral) / w_scale
    return -(w.w_energy * d_waste + w.w_wait * d_wait)


def group_waste_wait(
    prev: SimState, new: SimState, const: EngineConst, w: RewardWeights
) -> jnp.ndarray:
    """Like :func:`waste_wait_tradeoff`, but each node group's wasted energy
    is normalized by *that group's* active draw before averaging — on mixed
    platforms a cheap island's waste is no longer drowned out by the
    expensive one's scale, matching the group-targeted action space."""
    G = new.energy.shape[0]
    group_watts = jnp.maximum(
        jnp.zeros(G, jnp.float32).at[const.group_id].add(const.power[..., 3]),
        1e-6,
    )
    waste_states = (IDLE, SWITCHING_ON, SWITCHING_OFF)
    d_waste_g = sum(
        new.energy[:, k] - prev.energy[:, k] for k in waste_states
    )
    d_waste = jnp.mean(d_waste_g / (group_watts * 3600.0))
    N = new.node_state.shape[0]
    d_wait = (new.wait_integral - prev.wait_integral) / (
        jnp.float32(N) * 3600.0
    )
    return -(w.w_energy * d_waste + w.w_wait * d_wait)


def energy_wait(prev, new, const, w):
    """r = -(w_e * Δtotal_energy + w_w * Δaggregate_wait), normalized.

    The DVFS objective: mode choices move *ACTIVE*-state energy, which the
    waste-based rewards deliberately ignore — an agent commanding DVFS
    modes must be charged for total draw or turbo is free.
    """
    e_scale = _cluster_active_watts(const) * 3600.0
    d_e = (jnp.sum(new.energy) - jnp.sum(prev.energy)) / e_scale
    N = new.node_state.shape[0]
    d_wait = (new.wait_integral - prev.wait_integral) / (
        jnp.float32(N) * 3600.0
    )
    return -(w.w_energy * d_e + w.w_wait * d_wait)


def energy_only(prev, new, const, w):
    e_scale = _cluster_active_watts(const) * 3600.0
    return -(jnp.sum(new.energy) - jnp.sum(prev.energy)) / e_scale


def wait_only(prev, new, const, w):
    N = new.node_state.shape[0]
    return -(new.wait_integral - prev.wait_integral) / (jnp.float32(N) * 3600.0)


REWARDS = {
    "waste_wait": waste_wait_tradeoff,
    "group_waste_wait": group_waste_wait,
    "energy_wait": energy_wait,
    "energy_only": energy_only,
    "wait_only": wait_only,
}
