"""PPO learner (clipped surrogate) — the "swap the learning algorithm without
touching the core" demonstration of the paper's modular Config.py design.

Reuses the A2C rollout/GAE machinery; only the update rule differs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import EngineConst, SimState, make_const
from repro.core.rl.a2c import (
    Rollout,
    TrainState,
    _maybe_shard_update,
    _resolve_rollout_devices,
    collect_rollout,
    gae,
    make_batched_sims,
)
from repro.core.rl.env import EnvConfig, env_reset
from repro.core.rl.networks import policy_apply, policy_init
from repro.training.optimizer import adamw, apply_updates, clip_by_global_norm
from repro.workloads.platform import PlatformSpec
from repro.workloads.workload import Workload


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    n_envs: int = 32
    n_steps: int = 32
    gamma: float = 0.99
    gae_lambda: float = 0.95
    lr: float = 3e-4
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    max_grad_norm: float = 0.5
    n_epochs: int = 4
    n_minibatches: int = 4
    n_updates: int = 100
    hidden: Tuple[int, ...] = (128, 128)
    seed: int = 0


def ppo_loss(params, batch, cfg: PPOConfig):
    obs, actions, old_logp, advs, returns, mask = batch
    logits, values = jax.vmap(policy_apply, (None, 0))(params, obs)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, actions[..., None], axis=-1)[..., 0]
    n = jnp.maximum(jnp.sum(mask), 1.0)
    adv_n = (advs - jnp.sum(advs * mask) / n) / (
        jnp.sqrt(jnp.sum(jnp.square(advs) * mask) / n) + 1e-6
    )
    ratio = jnp.exp(logp - old_logp)
    unclipped = ratio * adv_n
    clipped = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv_n
    pg = -jnp.sum(jnp.minimum(unclipped, clipped) * mask) / n
    vf = jnp.sum(jnp.square(values - returns) * mask) / n
    ent = -jnp.sum(jnp.sum(jnp.exp(logp_all) * logp_all, -1) * mask) / n
    loss = pg + cfg.vf_coef * vf - cfg.ent_coef * ent
    return loss, {"pg_loss": pg, "vf_loss": vf, "entropy": ent}


def make_update_fn(
    env_cfg: EnvConfig,
    const: EngineConst,
    sims0: SimState,
    cfg: PPOConfig,
    optimizer=None,
    devices=None,
):
    """The jittable PPO update; ``devices`` shards the env batch across a
    1-D local-device mesh exactly like the A2C twin (data-parallel rollout
    + minibatch epochs over each shard's slice, psum-reduced gradients —
    core/SEMANTICS.md §Device-sharded sweeps, RL layer)."""
    opt = optimizer or adamw(lr=cfg.lr)
    D = _resolve_rollout_devices(devices, env_cfg, cfg.n_envs)

    def update(ts: TrainState, sims):
        if D is None:
            key_roll = ts.key
        else:
            # per-shard RNG (rollout actions + epoch shuffles); the carried
            # TrainState.key stays replicated
            key_roll = jax.random.fold_in(
                jax.random.split(ts.key)[1], jax.lax.axis_index("env")
            )
        env_states, obs, key, roll = collect_rollout(
            ts.params, ts.env_states, ts.obs, key_roll, sims, env_cfg,
            const, cfg.n_steps,
        )
        advs, returns = gae(roll, cfg.gamma, cfg.gae_lambda)
        # flatten [T, B] -> [T*B]
        logits, _ = jax.vmap(jax.vmap(policy_apply, (None, 0)), (None, 0))(
            ts.params, roll.obs
        )
        logp_all = jax.nn.log_softmax(logits)
        old_logp = jnp.take_along_axis(logp_all, roll.actions[..., None], -1)[..., 0]

        def flat(x):
            return x.reshape((-1,) + x.shape[2:])

        data = (
            flat(roll.obs),
            flat(roll.actions),
            jax.lax.stop_gradient(flat(old_logp)),
            flat(advs),
            flat(returns),
            flat(roll.live.astype(jnp.float32)),
        )
        n_total = data[0].shape[0]
        mb = n_total // cfg.n_minibatches

        def epoch(carry, _):
            params, opt_state, key = carry
            key, k = jax.random.split(key)
            perm = jax.random.permutation(k, n_total)

            def minibatch(carry, i):
                params, opt_state = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, i * mb, mb)
                batch = tuple(x[idx] for x in data)
                (loss, aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
                    params, batch, cfg
                )
                if D is not None:
                    # psum/D per-minibatch gradient reduction keeps params
                    # bit-identical on every device (the DDP invariant)
                    grads = jax.lax.pmean(grads, "env")
                grads, _ = clip_by_global_norm(grads, cfg.max_grad_norm)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                minibatch, (params, opt_state), jnp.arange(cfg.n_minibatches)
            )
            return (params, opt_state, key), jnp.mean(losses)

        (params, opt_state, key), losses = jax.lax.scan(
            epoch, (ts.params, ts.opt_state, key), None, length=cfg.n_epochs
        )
        if D is not None:
            key = jax.random.split(ts.key)[0]  # replicated successor
        mask = roll.live.astype(jnp.float32)
        metrics = {
            "loss": jnp.mean(losses),
            "mean_reward": jnp.sum(roll.rewards * mask)
            / jnp.maximum(jnp.sum(mask), 1.0),
        }
        if D is not None:
            metrics = {k: jax.lax.pmean(v, "env") for k, v in metrics.items()}
        return TrainState(params, opt_state, env_states, obs, key), metrics

    return _maybe_shard_update(update, sims0, D), opt


def train_ppo(
    platform: PlatformSpec,
    workloads: Sequence[Workload],
    env_cfg: EnvConfig,
    cfg: PPOConfig = PPOConfig(),
    progress: Optional[Callable[[int, dict], None]] = None,
    devices=None,
):
    """``devices`` shards the ``n_envs`` rollout batch across local devices
    (data-parallel + psum'd gradients — §Device-sharded sweeps, RL layer),
    falling back to ``env_cfg.engine.devices``; None = unsharded."""
    from repro.core.rl.env import shard_env_batch

    # closure constant of the jitted update: specialized policy flags (the
    # rollout traces only the RL stack's rules — §Static specialization)
    const = make_const(platform, env_cfg.engine, specialize=True)
    wls = list(workloads)
    if len(wls) < cfg.n_envs:
        wls = (wls * ((cfg.n_envs + len(wls) - 1) // len(wls)))[: cfg.n_envs]
    sims0 = make_batched_sims(platform, wls[: cfg.n_envs], env_cfg)
    sims0 = shard_env_batch(sims0, devices, env_cfg.engine)

    key = jax.random.PRNGKey(cfg.seed)
    key, kp = jax.random.split(key)
    params = policy_init(kp, env_cfg.obs_size, env_cfg.n_actions, cfg.hidden)
    update, opt = make_update_fn(env_cfg, const, sims0, cfg, devices=devices)
    opt_state = opt.init(params)
    env_states, obs = jax.vmap(functools.partial(env_reset, env_cfg, const))(sims0)
    ts = TrainState(params, opt_state, env_states, obs, key)

    update_j = jax.jit(update)
    history = []
    for i in range(cfg.n_updates):
        ts, metrics = update_j(ts)
        metrics = {k: float(v) for k, v in metrics.items()}
        history.append(metrics)
        if progress:
            progress(i, metrics)
    return ts.params, history
