"""Metrics extraction from a finished SimState (host-side)."""
from __future__ import annotations

from typing import Sequence, Union

import numpy as np

from repro.core.engine import SimState
from repro.core.types import ACTIVE, DONE, IDLE, SWITCHING_OFF, SWITCHING_ON, SimMetrics
from repro.workloads.platform import PlatformSpec


def _active_powers_and_names(power_active, n_groups):
    """Normalize the second argument of metrics_from_state.

    Accepts the legacy scalar active-watts, a per-group sequence, or a
    PlatformSpec (which also supplies group names).
    """
    if isinstance(power_active, PlatformSpec):
        return power_active.group_active_powers(), power_active.group_names()
    if np.ndim(power_active) == 0:
        return (float(power_active),) * n_groups, ()
    return tuple(float(p) for p in power_active), ()


def _dvfs_active_node_seconds(mode_energy, dvfs_watts):
    """Exact active node-seconds from the §DVFS ledgers: each ACTIVE node
    accrued ``watts[g, m] * dt`` into ``mode_energy[g, m]``, so dividing
    every cell by its own mode draw recovers the node-seconds exactly —
    the base-draw division is wrong as soon as a non-identity mode table
    ran (a zero-watt mode is unrecoverable from energy and contributes 0)."""
    me = np.asarray(mode_energy, np.float64)
    watts = np.asarray(dvfs_watts, np.float64)
    return float((me / np.where(watts > 0, watts, np.inf)).sum())


def metrics_from_state(
    s: SimState,
    power_active: Union[float, Sequence[float], PlatformSpec],
) -> SimMetrics:
    """Compute SimMetrics (same field semantics as the Python oracle).

    ``power_active`` recovers active node-seconds from active-state energy;
    pass the PlatformSpec (or a per-group sequence) for heterogeneous
    platforms so each group's energy is divided by its own draw. When a
    DVFS policy ran (the mode-residency ledger is non-zero) and the
    PlatformSpec is given, utilization instead uses the exact per-mode
    ledger division above — ACTIVE draw followed the mode table, not the
    base operating point.
    """
    s = np_state(s)
    exists = s["job_exists"]
    started = (s["job_start"] >= 0) & exists
    waits = (s["job_start"] - s["job_subtime"])[started]
    done = (s["job_status"] == DONE) & exists
    makespan = int(s["job_finish"][done].max()) if done.any() else 0
    energy_g = s["energy"].astype(np.float64)  # [G, 5]
    energy = energy_g.sum(axis=0)  # per-state totals
    total = float(energy.sum())
    wasted = float(energy[IDLE] + energy[SWITCHING_ON] + energy[SWITCHING_OFF])
    G = energy_g.shape[0]
    powers, names = _active_powers_and_names(power_active, G)
    dvfs_ran = float(s["mode_time"].sum()) > 0.0
    util = 0.0
    if makespan > 0:
        if dvfs_ran and isinstance(power_active, PlatformSpec):
            _, dvfs_watts, _ = power_active.group_dvfs_tables()
            active_node_s = _dvfs_active_node_seconds(
                s["mode_energy"], dvfs_watts
            )
        else:
            active_node_s = sum(
                energy_g[g, ACTIVE] / powers[g] for g in range(G) if powers[g]
            )
        util = float(active_node_s / (s["node_state"].shape[0] * makespan))
    return SimMetrics(
        total_energy_j=total,
        wasted_energy_j=wasted,
        energy_by_state_j=tuple(energy.tolist()),
        mean_wait_s=float(waits.mean()) if waits.size else 0.0,
        max_wait_s=float(waits.max()) if waits.size else 0.0,
        utilization=util,
        makespan_s=makespan,
        n_jobs=int(exists.sum()),
        n_terminated=int((s["job_terminated"] & done).sum()),
        energy_by_group_j=tuple(tuple(row) for row in energy_g.tolist()),
        group_names=names,
        mode_residency_s=tuple(
            tuple(row) for row in s["mode_time"].astype(np.float64).tolist()
        ),
        energy_by_mode_j=tuple(
            tuple(row) for row in s["mode_energy"].astype(np.float64).tolist()
        ),
        truncated=bool(s["truncated"]),
    )


def np_state(s: SimState) -> dict:
    return {k: np.asarray(v) for k, v in s._asdict().items()}


def schedule_table(s: SimState) -> np.ndarray:
    """(n_jobs, 3) [start, finish(-1 if not done), terminated] — parity format
    matching PyDES.schedule_table()."""
    d = np_state(s)
    exists = d["job_exists"]
    start = d["job_start"].astype(np.float64)
    finish = np.where(d["job_status"] == DONE, d["job_finish"], -1).astype(np.float64)
    term = d["job_terminated"].astype(np.float64)
    out = np.stack([start, finish, term], axis=-1)
    return out[exists]
