"""Gantt-chart export (paper §3.1, Fig. 3).

Consumes either the Python oracle's interval list or the JAX engine's
:class:`GanttLog` snapshots, producing a per-node interval table, a CSV file,
and (when matplotlib is available) a PNG with the paper's color scheme:
light blue = idle, dark blue = sleeping, red = switching off,
green = switching on, colored blocks = jobs, black = terminated jobs.
"""
from __future__ import annotations

import csv
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import ACTIVE, IDLE, SLEEP, STATE_NAMES, SWITCHING_OFF, SWITCHING_ON

Interval = Tuple[float, float, int, int, int]  # t0, t1, node, state, job


def intervals_from_log(log) -> List[Interval]:
    """Convert a JAX GanttLog (per-batch snapshots) into merged intervals."""
    n = int(log.n)
    t0 = np.asarray(log.t0)[:n]
    t1 = np.asarray(log.t1)[:n]
    state = np.asarray(log.state)[:n]
    job = np.asarray(log.job)[:n]
    out: List[Interval] = []
    n_nodes = state.shape[1] if n else 0
    for nid in range(n_nodes):
        cur: Optional[List] = None
        for i in range(n):
            s, j = int(state[i, nid]), int(job[i, nid])
            if cur is not None and cur[3] == s and cur[4] == j and cur[1] == t0[i]:
                cur[1] = t1[i]
            else:
                if cur is not None and cur[1] > cur[0]:
                    out.append(tuple(cur))
                cur = [float(t0[i]), float(t1[i]), nid, s, j]
        if cur is not None and cur[1] > cur[0]:
            out.append(tuple(cur))
    return out


def write_csv(intervals: Sequence[Interval], path: str) -> None:
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["t0", "t1", "node", "state", "state_name", "job"])
        for t0, t1, nid, st, job in sorted(intervals, key=lambda r: (r[2], r[0])):
            w.writerow([t0, t1, nid, st, STATE_NAMES[st], job])


def render_png(
    intervals: Sequence[Interval],
    path: str,
    terminated_jobs: Sequence[int] = (),
    title: str = "SPARS-X Gantt",
) -> bool:
    """Render the paper's Fig.-3-style Gantt. Returns False if matplotlib
    is unavailable."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        from matplotlib.patches import Patch
    except ImportError:  # pragma: no cover
        return False

    term = set(int(j) for j in terminated_jobs)
    state_colors = {
        IDLE: "#add8e6",  # light blue
        SLEEP: "#00008b",  # dark blue
        SWITCHING_ON: "#2e8b57",  # green
        SWITCHING_OFF: "#cc2222",  # red
    }
    cmap = [
        "#e6994c", "#8cc04c", "#4cc0a8", "#4c8cc0", "#a84cc0",
        "#c04c6e", "#c0b24c", "#6ec04c", "#4cc0c0", "#7a4cc0",
    ]
    nodes = sorted({r[2] for r in intervals})
    fig, ax = plt.subplots(figsize=(14, max(3, 0.35 * len(nodes) + 1)))
    labeled = set()
    for t0, t1, nid, st, job in intervals:
        if st == ACTIVE:
            color = "black" if job in term else cmap[job % len(cmap)]
        else:
            color = state_colors.get(st, "#dddddd")
        ax.barh(nid, t1 - t0, left=t0, height=0.9, color=color, linewidth=0)
        if st == ACTIVE and job not in labeled and job not in term and t1 - t0 > 0:
            ax.text((t0 + t1) / 2, nid, str(job), ha="center", va="center", fontsize=6)
            labeled.add(job)
    ax.set_xlabel("simulation time (s)")
    ax.set_ylabel("compute node")
    ax.set_title(title)
    ax.legend(
        handles=[
            Patch(color="#add8e6", label="idle"),
            Patch(color="#00008b", label="sleeping"),
            Patch(color="#2e8b57", label="switching on"),
            Patch(color="#cc2222", label="switching off"),
            Patch(color="black", label="terminated job"),
        ],
        loc="upper right",
        fontsize=7,
    )
    fig.tight_layout()
    fig.savefig(path, dpi=130)
    plt.close(fig)
    return True
