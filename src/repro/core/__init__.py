# The paper's primary contribution: an event-batched, power-state-aware HPC
# scheduling simulator with an RL interface, vectorized for TPU (see
# core/SEMANTICS.md for the exact engine contract shared with the Python
# reference oracle in core/ref/pydes.py).
from repro.core.types import (
    BasePolicy,
    EngineConfig,
    PSMVariant,
    SimMetrics,
)
from repro.core.engine import (
    EngineConst,
    SimState,
    init_state,
    make_const,
    next_time,
    process_batch,
    run_sim,
    run_sim_gantt,
    simulate,
)
from repro.core.metrics import metrics_from_state, schedule_table

__all__ = [
    "BasePolicy",
    "EngineConfig",
    "PSMVariant",
    "SimMetrics",
    "EngineConst",
    "SimState",
    "init_state",
    "make_const",
    "next_time",
    "process_batch",
    "run_sim",
    "run_sim_gantt",
    "simulate",
    "metrics_from_state",
    "schedule_table",
]
