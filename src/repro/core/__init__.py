# The paper's primary contribution: an event-batched, power-state-aware HPC
# scheduling simulator with an RL interface, vectorized for TPU (see
# core/SEMANTICS.md for the exact engine contract shared with the Python
# reference oracle in core/ref/pydes.py). Power management is a composable
# policy layer (core/policy.py); PSMVariant survives as a deprecation shim.
from repro.core.types import (
    BasePolicy,
    EngineConfig,
    PSMVariant,
    SimMetrics,
)
from repro.core.policy import (
    IPM,
    AlwaysOn,
    PolicyParams,
    PowerPolicy,
    RLController,
    TimeoutSleep,
    from_label,
    label_of,
    policy_from_psm,
    scheduler_labels,
)
from repro.core.engine import (
    EngineConst,
    SimBatch,
    SimState,
    init_state,
    make_const,
    next_time,
    process_batch,
    run_sim,
    run_sim_gantt,
    simulate,
    sweep,
)
from repro.core.metrics import metrics_from_state, schedule_table

__all__ = [
    "BasePolicy",
    "EngineConfig",
    "PSMVariant",
    "SimMetrics",
    "PowerPolicy",
    "AlwaysOn",
    "TimeoutSleep",
    "IPM",
    "RLController",
    "from_label",
    "label_of",
    "policy_from_psm",
    "scheduler_labels",
    "EngineConst",
    "SimBatch",
    "SimState",
    "init_state",
    "make_const",
    "next_time",
    "process_batch",
    "run_sim",
    "run_sim_gantt",
    "simulate",
    "sweep",
    "metrics_from_state",
    "schedule_table",
]
