"""Heap-ordered sequential Python DES — the Batsim-like reference oracle.

This is the *baseline the paper compares against*: a conventional sequential
discrete-event simulator. It implements core/SEMANTICS.md exactly and serves
as the correctness oracle for the vectorized JAX engine, and as the runtime
baseline for the Table-4 speedup benchmark.

``split_simultaneous_events=True`` reproduces the Batsim bug of the paper's
Fig. 1: same-timestamp job completions are delivered to the scheduler one at
a time (separate "messages"), so the scheduler decides on partial
information and schedules can diverge from the atomic-batch semantics.

Float64 time/energy is used here; the JAX engine uses int32 time + f32
compensated energy. Parity tests bound the difference.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import (
    ACTIVE,
    ALLOCATED,
    DONE,
    IDLE,
    INF_TIME,
    RUNNING,
    SLEEP,
    SWITCHING_OFF,
    SWITCHING_ON,
    WAITING,
    EngineConfig,
    SimMetrics,
)
from repro.workloads.platform import PlatformSpec
from repro.workloads.workload import Workload

INF = float(INF_TIME)


@dataclasses.dataclass
class _Node:
    nid: int
    state: int = IDLE
    until: float = INF
    job: int = -1
    idle_since: float = 0.0


@dataclasses.dataclass
class _Job:
    jid: int  # index in submission order
    res: int
    subtime: int
    reqtime: int
    runtime: int  # nominal work at speed 1
    eff_runtime: int  # realized effective runtime (resolved at start)
    terminated: bool
    status: int = WAITING
    start: float = -1.0
    finish: float = INF
    alloc_ready: float = INF  # predicted start recorded at allocation
    speed: float = 1.0  # current effective speed (DVFS rescale anchor, f32)


class PyDES:
    """Sequential reference engine. See module docstring."""

    def __init__(
        self,
        platform: PlatformSpec,
        workload: Workload,
        config: EngineConfig,
        split_simultaneous_events: bool = False,
        rl_policy: Optional[Callable] = None,
        start_state: int = IDLE,
    ):
        self.p = platform
        self.cfg = config
        # the traced policy axis, as concrete host values: the oracle
        # mirrors the engine's flag-gated superset program — a concrete
        # `if flag:` is the sequential spelling of the engine's
        # `jnp.where(flag, ...)` gates (core/SEMANTICS.md §Traced policy
        # axis), so both engines stay bit-exact per scenario
        self.pp = config.policy.params(config.base)
        self.split = split_simultaneous_events
        self.rl_policy = rl_policy
        # per-node platform tables (core/SEMANTICS.md §Heterogeneity);
        # identical semantics to the JAX engine's EngineConst
        self.power = platform.node_power_table()  # f32[N, 5]
        self.t_on = platform.node_t_switch_on()  # i32[N]
        self.t_off = platform.node_t_switch_off()  # i32[N]
        self.speed = platform.node_speed()  # f32[N]
        if config.node_order == "idle-watts":
            self.okey = self.power[:, IDLE]  # f32[N] idle draw
        elif config.node_order == "pack":
            # dynamic packing key, recomputed per scheduler pass (twin of
            # policy.pack_key); the static key is unused
            self.okey = np.zeros(platform.nb_nodes, np.float32)
        else:
            self.okey = platform.node_order_key()  # f32[N]
        self._pack: Optional[np.ndarray] = None  # frozen per-pass pack key
        self.gid = platform.node_group_id()  # i32[N]
        self.n_groups = platform.n_groups()
        # per-group power rows for the grouped-tables accrual (groups are
        # internally uniform by construction — core/tables.py validates the
        # same invariant on the engine side)
        self.group_power = [g.power_table() for g in platform.groups()]
        # runtime DVFS mode tables + state (core/SEMANTICS.md §DVFS)
        self.dvfs_speed, self.dvfs_watts, self.dvfs_n_modes = (
            platform.group_dvfs_tables()
        )
        self.mode = [0] * self.n_groups  # current mode per group
        M = self.dvfs_speed.shape[1]
        self.mode_time = [[0.0] * M for _ in range(self.n_groups)]
        self.mode_energy = [[0.0] * M for _ in range(self.n_groups)]
        # rule 10 (§Forecast) EWMA predictor operands + state; horizon/alpha
        # resolution mirrors engine.make_const exactly (EngineConfig wins
        # for the horizon; a Forecast policy's fields are the fallback
        # defaults), and the inits mirror engine.init_state
        horizon = config.forecast_horizon
        if horizon is None:
            horizon = getattr(config.policy, "horizon", None) or 0
        alpha = getattr(config.policy, "alpha", None)
        if alpha is None:
            alpha = config.forecast_alpha
        self.fc_horizon = int(horizon)
        self.fc_alpha = np.float32(alpha)
        self.fc_gap = np.float32(float(INF_TIME))
        self.fc_res = np.float32(0.0)
        self.fc_last_arr = 0
        self.fc_prev_t = -1

        wl = workload.sorted_by_subtime()
        self.jobs: List[_Job] = []
        for i, j in enumerate(wl.jobs):
            # realized wall time (work / slowest allocated node's speed) and
            # the overrun verdict are resolved at job start; eff_runtime
            # starts as the nominal work
            self.jobs.append(
                _Job(i, j.res, j.subtime, j.reqtime, j.runtime, j.runtime, False)
            )
        self.nodes = [
            _Node(i, state=start_state, idle_since=0.0)
            for i in range(platform.nb_nodes)
        ]
        self.t = 0.0
        self.energy_by_group = [[0.0] * 5 for _ in range(self.n_groups)]
        self.n_batches = 0
        self.truncated = False  # set by run() when the batch cap bites
        self.gantt: List[Tuple[float, float, int, int, int]] = []  # (t0,t1,node,state,job)
        self._gantt_open: Dict[int, Tuple[float, int, int]] = {}
        if config.record_gantt:
            for nd in self.nodes:
                self._gantt_open[nd.nid] = (0.0, nd.state, -1)
        # profiling counters (Table-4-style breakdown)
        self.counters = {
            "sim_advance": 0,
            "scheduling": 0,
            "resource": 0,
            "job_lifecycle": 0,
            "monitoring": 0,
            "timeout_policy": 0,
        }

    @property
    def energy_by_state(self) -> List[float]:
        """Per-state energy summed over node groups (legacy view)."""
        return [
            sum(g[k] for g in self.energy_by_group) for k in range(5)
        ]

    def _eff_speed(self, nid: int) -> np.float32:
        """Node speed under the current DVFS mode (§DVFS); base otherwise."""
        if self.pp.dvfs_enabled:
            g = int(self.gid[nid])
            return np.float32(self.dvfs_speed[g, self.mode[g]])
        return np.float32(self.speed[nid])

    # ---------- ready times (SEMANTICS.md variant table) ----------
    def _ready(self, nd: _Node) -> float:
        if self.pp.eager_ready:
            return self.t
        if nd.state == IDLE:
            return self.t
        if nd.state == SWITCHING_ON:
            return nd.until
        if nd.state == SLEEP:
            return self.t + float(self.t_on[nd.nid])
        if nd.state == SWITCHING_OFF:
            return nd.until + float(self.t_on[nd.nid])
        return INF  # ACTIVE (not eligible anyway)

    def _sort_key(self, nd: _Node):
        """Allocation order (SEMANTICS.md §Heterogeneity): (ready, [key,] nid)."""
        if self.cfg.node_order == "pack":
            return (self._ready(nd), self._pack[nd.nid], nd.nid)
        if self.cfg.node_order != "id":
            return (self._ready(nd), self.okey[nd.nid], nd.nid)
        return (self._ready(nd), nd.nid)

    def _pack_key(self) -> np.ndarray:
        """f32[N] queue-aware packing key — twin of ``policy.pack_key``.

        Fewest-idle groups first; currently-idle unreserved nodes sort
        strictly before sleeping/transitioning ones (N + 1 band offset).
        Frozen for the duration of one scheduler pass.
        """
        N = len(self.nodes)
        counts = [0] * self.n_groups
        for nd in self.nodes:
            if nd.job < 0 and nd.state == IDLE:
                counts[int(self.gid[nd.nid])] += 1
        key = np.zeros(N, np.float32)
        for nd in self.nodes:
            band = 0 if (nd.job < 0 and nd.state == IDLE) else N + 1
            key[nd.nid] = np.float32(counts[int(self.gid[nd.nid])] + band)
        return key

    def _occupancy(self) -> List[List[int]]:
        """[G][5] per-(group, state) node histogram — twin of the engine's
        ``_occupancy`` (core/SEMANTICS.md §Group-indexed tables)."""
        occ = [[0] * 5 for _ in range(self.n_groups)]
        for nd in self.nodes:
            occ[int(self.gid[nd.nid])][nd.state] += 1
        return occ

    def _group_draw(self, occ: List[List[int]]) -> List[List[float]]:
        """[G][5] occupancy-weighted watts (occ · power, DVFS-aware) —
        twin of the engine's ``_group_draw``; f64 here vs the engine's f32
        contraction, so parity is to rounding like the dense path."""
        dvfs_on = self.pp.dvfs_enabled
        draw = [[0.0] * 5 for _ in range(self.n_groups)]
        for g in range(self.n_groups):
            row = self.group_power[g]
            for st in range(5):
                w = float(row[st])
                if dvfs_on and st == ACTIVE:
                    # ACTIVE draw follows the group's DVFS mode (§DVFS)
                    w = float(self.dvfs_watts[g, self.mode[g]])
                draw[g][st] = w * occ[g][st]
        return draw

    def _gantt_mark(self, nd: _Node) -> None:
        if not self.cfg.record_gantt:
            return
        t0, st, job = self._gantt_open[nd.nid]
        if st != nd.state or job != nd.job_for_gantt:
            if self.t > t0:
                self.gantt.append((t0, self.t, nd.nid, st, job))
            self._gantt_open[nd.nid] = (self.t, nd.state, nd.job_for_gantt)

    # ---------- allocation ----------
    def _eligible(self) -> List[_Node]:
        return [nd for nd in self.nodes if nd.job < 0]

    def _partition_select(self, elig_sorted: List[_Node], res: int):
        """Partition-aware pick (SEMANTICS.md §Partition-aware allocation) —
        host twin of the engine's ``_partition_pick`` masked cumsum.

        Scanning the sorted eligible nodes in allocation order, the first
        group to accumulate ``res`` nodes wins (the earliest-completing
        group); its first ``res`` eligible nodes are the allocation.
        Returns None when no single group can hold the job.
        """
        per_group: Dict[int, List[_Node]] = {}
        for nd in elig_sorted:
            g = int(self.gid[nd.nid])
            bucket = per_group.setdefault(g, [])
            if len(bucket) < res:
                bucket.append(nd)
                if len(bucket) == res:
                    return bucket
        return None

    def _try_allocate(
        self, job: _Job, shadow: Optional[float], extra: Optional[int]
    ) -> bool:
        """Allocate per SEMANTICS.md rule 4. shadow/extra set => backfill test."""
        self.counters["resource"] += 1
        elig = self._eligible()
        if len(elig) < job.res:
            return False
        elig.sort(key=self._sort_key)
        if self.cfg.allocation == "partition":
            # §Partition-aware allocation: no cross-group allocations — the
            # job fails to start when no single group fits it
            picked = self._partition_select(elig, job.res)
            if picked is None:
                return False
            chosen = picked
        else:
            chosen = elig[: job.res]
        ready = max(self._ready(nd) for nd in chosen)
        if shadow is not None:
            pred_completion = ready + job.reqtime
            if not (pred_completion <= shadow or job.res <= extra):
                return False
        for nd in chosen:
            nd.job = job.jid
            if nd.state == SLEEP:
                nd.state = SWITCHING_ON
                nd.until = self.t + float(self.t_on[nd.nid])
                self._gantt_mark(nd)
        job.status = ALLOCATED
        job.alloc_ready = ready
        return True

    def _shadow(self, head: _Job) -> Tuple[float, int]:
        """EASY shadow time S and extra count E (SEMANTICS.md)."""
        rel = []
        for nd in self.nodes:
            if nd.job < 0:
                rel.append(self._ready(nd))
            else:
                j = self.jobs[nd.job]
                if j.status == RUNNING:
                    rel.append(j.start + j.reqtime)
                elif j.status == ALLOCATED:
                    rel.append(j.alloc_ready + j.reqtime)
                else:  # DONE shouldn't hold nodes
                    rel.append(self.t)
        rel.sort()
        # head.res can exceed N (an unsatisfiable request); clamp like the
        # JAX engine's out-of-bounds gather does
        S = rel[min(head.res, len(rel)) - 1]
        E = sum(1 for r in rel if r <= S) - head.res
        return S, E

    # ---------- one scheduler pass (rule 4) ----------
    def _scheduler_pass(self) -> None:
        # merge_bursts mirrors the engine's repeat rule exactly: re-run the
        # pass at the same t while it allocated something AND arrived
        # WAITING jobs remain, so a burst wider than the window W drains in
        # one batch. Only the pass repeats — job starts (rule 5) still run
        # once per batch, after it.
        while True:
            self.counters["scheduling"] += 1
            if self.cfg.node_order == "pack":
                self._pack = self._pack_key()  # frozen for this pass
            queue = [
                j
                for j in self.jobs
                if j.status == WAITING and j.subtime <= self.t
            ][: self.cfg.window]
            shadow = extra = None
            n_alloc = 0
            for j in queue:
                if shadow is None:
                    ok = self._try_allocate(j, None, None)
                    if ok:
                        n_alloc += 1
                    elif not self.pp.backfill:  # FCFS: stop at first failure
                        break
                    else:
                        shadow, extra = self._shadow(j)
                else:
                    if self._try_allocate(j, shadow, extra):
                        # S stays fixed for the batch; backfilled job
                        # consumed res of the extra nodes
                        n_alloc += 1
                        extra = max(0, extra - j.res)
            if not self.cfg.merge_bursts or n_alloc == 0:
                return
            if not any(
                j.status == WAITING and j.subtime <= self.t
                for j in self.jobs
            ):
                return

    # ---------- job starts (rule 5) ----------
    def _start_jobs(self) -> None:
        self.counters["job_lifecycle"] += 1
        per_job_ready: Dict[int, int] = {}
        for nd in self.nodes:
            if nd.job >= 0 and nd.state == IDLE:
                per_job_ready[nd.job] = per_job_ready.get(nd.job, 0) + 1
        for jid, cnt in sorted(per_job_ready.items()):
            j = self.jobs[jid]
            if j.status == ALLOCATED and cnt == j.res:
                # realized runtime = work / slowest allocated node; the f32
                # expression is the cross-engine contract (SEMANTICS.md
                # §Heterogeneity) — the JAX engine evaluates the identical
                # float32 ceil, keeping schedule tables bit-exact
                speed_min = min(
                    self._eff_speed(nd.nid)
                    for nd in self.nodes
                    if nd.job == jid
                )
                realized = max(
                    int(np.ceil(np.float32(j.runtime) / speed_min)), 1
                )
                if self.cfg.terminate_overrun:
                    j.eff_runtime = min(realized, j.reqtime)
                    j.terminated = realized > j.reqtime
                else:
                    j.eff_runtime = realized
                    j.terminated = False
                j.speed = speed_min
                j.status = RUNNING
                j.start = self.t
                j.finish = self.t + j.eff_runtime
                for nd in self.nodes:
                    if nd.job == jid:
                        nd.state = ACTIVE
                        nd.until = INF
                        self._gantt_mark(nd)

    # ---------- PSM rules 6-8 ----------
    def _queued_demand(self) -> int:
        return sum(
            j.res
            for j in self.jobs
            if j.status == WAITING and j.subtime <= self.t
        )

    def _timeout_switch_off(self, ipm_cap: bool = False) -> None:
        """Rule 6; ``ipm_cap`` caps switch-offs by queued demand (PSAS+IPM)."""
        self.counters["timeout_policy"] += 1
        timeout = self.cfg.timeout
        if timeout is None:
            return
        cands = [
            nd
            for nd in self.nodes
            if nd.job < 0
            and nd.state == IDLE
            and self.t - nd.idle_since >= timeout
        ]
        cands.sort(key=lambda nd: (nd.idle_since, nd.nid))
        if ipm_cap:
            avail = sum(
                1
                for nd in self.nodes
                if nd.job < 0 and nd.state in (IDLE, SWITCHING_ON)
            )
            surplus = max(0, avail - self._queued_demand())
            cands = cands[:surplus]
        for nd in cands:
            nd.state = SWITCHING_OFF
            nd.until = self.t + float(self.t_off[nd.nid])
            self._gantt_mark(nd)

    def _ipm_wake(self) -> None:
        avail = sum(
            1
            for nd in self.nodes
            if nd.job < 0 and nd.state in (IDLE, SWITCHING_ON)
        )
        deficit = self._queued_demand() - avail
        if deficit <= 0:
            return
        for nd in self.nodes:
            if deficit <= 0:
                break
            if nd.job < 0 and nd.state == SLEEP:
                nd.state = SWITCHING_ON
                nd.until = self.t + float(self.t_on[nd.nid])
                self._gantt_mark(nd)
                deficit -= 1

    def _apply_rl(self, n_on, n_off) -> None:
        """Rule 8: wake lowest-id sleeping; sleep longest-idle unreserved.

        Global mode takes scalar counts (sequences are summed); grouped mode
        (``pp.rl_grouped``) takes ``[G]`` per-group counts and selects
        within each node group independently (core/policy.py).
        """
        grouped = self.pp.rl_grouped
        if grouped:
            # per-group budgets, indexed by the node's group id
            on_budget = [int(v) for v in np.asarray(n_on).reshape(-1)]
            off_budget = [int(v) for v in np.asarray(n_off).reshape(-1)]
        else:
            # global budgets shared by every node (one-element view)
            on_budget = [int(np.sum(n_on))]
            off_budget = [int(np.sum(n_off))]

        def bucket(nd):
            return int(self.gid[nd.nid]) if grouped else 0

        for nd in self.nodes:
            if nd.job < 0 and nd.state == SLEEP and on_budget[bucket(nd)] > 0:
                on_budget[bucket(nd)] -= 1
                nd.state = SWITCHING_ON
                nd.until = self.t + float(self.t_on[nd.nid])
                self._gantt_mark(nd)
        cands = [
            nd for nd in self.nodes if nd.job < 0 and nd.state == IDLE
        ]
        cands.sort(key=lambda nd: (nd.idle_since, nd.nid))
        for nd in cands:
            if off_budget[bucket(nd)] > 0:
                off_budget[bucket(nd)] -= 1
                nd.state = SWITCHING_OFF
                nd.until = self.t + float(self.t_off[nd.nid])
                self._gantt_mark(nd)

    def _apply_dvfs_modes(self, target: List[int]) -> None:
        """Install a per-group mode vector + remaining-work rescale — the
        shared tail of rules 9 and 10.

        Concrete twin of ``policy.apply_dvfs_modes``: the rescale uses the
        identical float32 expression, so schedules stay bit-exact across
        engines.
        """
        for g in range(self.n_groups):
            self.mode[g] = int(target[g])
        # rescale running, non-terminated jobs whose allocation speed changed
        for j in self.jobs:
            if j.status != RUNNING or j.terminated:
                continue
            speed_min = min(
                self._eff_speed(nd.nid)
                for nd in self.nodes
                if nd.job == j.jid
            )
            if speed_min == np.float32(j.speed):
                continue
            rem = np.float32(max(j.finish - self.t, 1.0))
            work = rem * np.float32(j.speed)  # f32 contract expression
            new_rem = max(int(np.ceil(np.float32(work / speed_min))), 1)
            new_finish = self.t + new_rem
            if self.cfg.terminate_overrun:
                cap = j.start + j.reqtime
                if new_finish > cap:
                    new_finish = cap
                    j.terminated = True
            j.finish = float(new_finish)
            j.eff_runtime = int(j.finish - j.start)
            j.speed = speed_min

    def _apply_dvfs(self, mode_cmd=None) -> None:
        """Rule 9 (§DVFS): per-group mode selection; the mode install +
        remaining-work rescale is the shared :meth:`_apply_dvfs_modes` tail.

        Concrete twin of ``policy.apply_dvfs``: the heuristic ladder uses
        the identical integer expression.
        """
        N = len(self.nodes)
        if self.pp.dvfs_rl:
            target = list(self.mode)
            if mode_cmd is not None:
                for g, c in enumerate(np.asarray(mode_cmd).reshape(-1)):
                    if c >= 0:
                        target[g] = int(
                            min(max(int(c), 0), int(self.dvfs_n_modes[g]) - 1)
                        )
        else:
            demand = self._queued_demand()
            target = [
                min(
                    int(self.dvfs_n_modes[g]) - 1,
                    (demand * int(self.dvfs_n_modes[g])) // N,
                )
                for g in range(self.n_groups)
            ]
        self._apply_dvfs_modes(target)

    def _forecast_pressure(self) -> int:
        """Predicted extra node demand over the horizon (rule 10) —
        concrete twin of ``policy.forecast_pressure`` (identical float32
        expressions, so both engines floor the same value)."""
        gap = max(self.fc_gap, np.float32(1.0))
        horizon = np.float32(self.fc_horizon)
        pressure = (horizon / gap) * self.fc_res
        N = len(self.nodes)
        return int(
            min(max(np.floor(pressure), np.float32(0.0)), np.float32(N))
        )

    def _apply_forecast(self) -> None:
        """Rule 10 (§Forecast): EWMA predictor update, proactive wake, and
        the optional DVFS pre-ramp.

        Concrete twin of ``policy.apply_forecast``: the EWMA updates use the
        identical float32 expressions (strict form ``a*obs + (1-a)*ewma``
        from the same inits, so ``alpha=0`` freezes them and the rule is a
        provable no-op), the wake selects lowest-id sleeping nodes exactly
        like the engine's cumsum mask, and the pre-ramp never drops below
        rule 9's current mode.
        """
        t = int(self.t)
        # predictor update (EWMA over this batch's arrival burst)
        newly = [j for j in self.jobs if self.fc_prev_t < j.subtime <= t]
        if newly:
            denom = np.float32(len(newly))
            gap_obs = np.float32(t - self.fc_last_arr) / denom
            res_obs = np.float32(sum(j.res for j in newly)) / denom
            a = self.fc_alpha
            one = np.float32(1.0)
            self.fc_gap = a * gap_obs + (one - a) * self.fc_gap
            self.fc_res = a * res_obs + (one - a) * self.fc_res
            self.fc_last_arr = t
        self.fc_prev_t = t
        # proactive wake fires only on positive predicted pressure — a
        # zero-horizon (or never-updated) predictor must leave the stack
        # bit-exact with its reactive base, not degenerate into IPM
        f_extra = self._forecast_pressure()
        if f_extra <= 0:
            return
        avail = sum(
            1
            for nd in self.nodes
            if nd.job < 0 and nd.state in (IDLE, SWITCHING_ON)
        )
        budget = self._queued_demand() + f_extra - avail
        for nd in self.nodes:  # lowest id first (engine: cumsum <= deficit)
            if budget <= 0:
                break
            if nd.job < 0 and nd.state == SLEEP:
                nd.state = SWITCHING_ON
                nd.until = self.t + float(self.t_on[nd.nid])
                self._gantt_mark(nd)
                budget -= 1
        # DVFS pre-ramp: never below rule 9's current mode
        if not self.pp.forecast_dvfs:
            return
        N = len(self.nodes)
        demand = self._queued_demand() + f_extra
        target = [
            max(
                self.mode[g],
                min(
                    int(self.dvfs_n_modes[g]) - 1,
                    (demand * int(self.dvfs_n_modes[g])) // N,
                ),
            )
            for g in range(self.n_groups)
        ]
        self._apply_dvfs_modes(target)

    # ---------- event machinery ----------
    def _next_time(self) -> float:
        self.counters["sim_advance"] += 1
        cand = [INF]
        for j in self.jobs:
            if j.status == WAITING and j.subtime > self.t:
                cand.append(float(j.subtime))
            elif j.status == RUNNING:
                cand.append(j.finish)
        for nd in self.nodes:
            if nd.state in (SWITCHING_ON, SWITCHING_OFF):
                cand.append(nd.until)
        # policy-axis candidates, mirroring the engine's flag gates:
        # idle-timeout expiries (sleep_enabled) and the RL decision tick
        if self.pp.sleep_enabled and self.cfg.timeout is not None:
            cand.extend(
                nd.idle_since + self.cfg.timeout
                for nd in self.nodes
                if nd.job < 0 and nd.state == IDLE
            )
        if self.pp.rl_enabled and self.cfg.rl_decision_interval:
            cand.append(self.t + self.cfg.rl_decision_interval)
        if self.pp.forecast_enabled and self.fc_horizon > 0:
            # rule 10 review tick (twin of the engine's _time_candidates):
            # re-evaluate the forecast at most one horizon after each batch
            cand.append(self.t + self.fc_horizon)
        # strictly future events only: an expired-but-guard-blocked timeout
        # otherwise wedges the clock (the guard is re-evaluated at every batch)
        nt = min((c for c in cand if c > self.t), default=INF)
        return nt

    def _accrue(self, t_next: float) -> None:
        self.counters["monitoring"] += 1
        dt = t_next - self.t
        if dt <= 0:
            return
        dvfs_on = self.pp.dvfs_enabled
        if self.cfg.grouped_tables:
            # grouped accrual — the contraction occ[G, 5] · power[G, 5]
            # instead of the dense per-node sum
            draw = self._group_draw(self._occupancy())
            for g in range(self.n_groups):
                for st in range(5):
                    self.energy_by_group[g][st] += draw[g][st] * dt
                if dvfs_on:
                    self.mode_energy[g][self.mode[g]] += draw[g][ACTIVE] * dt
        else:
            for nd in self.nodes:
                g = int(self.gid[nd.nid])
                draw = float(self.power[nd.nid, nd.state])
                if dvfs_on and nd.state == ACTIVE:
                    # ACTIVE draw follows the group's DVFS mode (§DVFS)
                    draw = float(self.dvfs_watts[g, self.mode[g]])
                    self.mode_energy[g][self.mode[g]] += draw * dt
                self.energy_by_group[g][nd.state] += draw * dt
        if dvfs_on:
            for g in range(self.n_groups):
                self.mode_time[g][self.mode[g]] += dt

    def _process_batch(self) -> None:
        t = self.t
        # 1. completions
        completed = [j for j in self.jobs if j.status == RUNNING and j.finish <= t]
        if self.split and len(completed) > 1:
            # Batsim bug mode: deliver completions one at a time, running the
            # scheduler between deliveries (paper Fig. 1).
            for j in completed:
                self._complete(j)
                self._transitions(t)
                self._scheduler_pass()
                self._start_jobs()
        else:
            for j in completed:
                self._complete(j)
            self._transitions(t)
        # 3. arrivals are implicit (queue = WAITING & subtime <= t)
        # 4-5. schedule + start
        self._scheduler_pass()
        self._start_jobs()
        # 6-10. power management: the same flag-gated rule sequence as the
        # engine's _power_step (a disabled rule selects no nodes there;
        # here it is simply skipped — identical state either way)
        if self.pp.sleep_enabled:
            self._timeout_switch_off(ipm_cap=self.pp.ipm_enabled)
        if self.pp.ipm_enabled:
            self._ipm_wake()
        mode_cmd = None
        if self.pp.rl_enabled and self.rl_policy is not None:
            cmds = self.rl_policy(self)
            mode_cmd = cmds[2] if len(cmds) > 2 else None
            self._apply_rl(cmds[0], cmds[1])
            self._start_jobs()
        if self.pp.dvfs_enabled:
            self._apply_dvfs(mode_cmd)
        if self.pp.forecast_enabled:
            self._apply_forecast()

    def _complete(self, j: _Job) -> None:
        self.counters["job_lifecycle"] += 1
        j.status = DONE
        for nd in self.nodes:
            if nd.job == j.jid:
                nd.job = -1
                nd.state = IDLE
                nd.until = INF
                nd.idle_since = self.t
                self._gantt_mark(nd)

    def _transitions(self, t: float) -> None:
        for nd in self.nodes:
            if nd.until <= t and nd.state == SWITCHING_ON:
                nd.state = IDLE
                nd.until = INF
                nd.idle_since = t
                self._gantt_mark(nd)
            elif nd.until <= t and nd.state == SWITCHING_OFF:
                nd.state = SLEEP
                nd.until = INF
                self._gantt_mark(nd)
                if nd.job >= 0:  # reserved while shutting down: chain to on
                    nd.state = SWITCHING_ON
                    nd.until = t + float(self.t_on[nd.nid])
                    self._gantt_mark(nd)

    def run(self, max_batches: Optional[int] = None) -> SimMetrics:
        limit = max_batches or self.cfg.max_batches or (
            20 * len(self.jobs) + 10000
        )
        # t=0 batch (arrivals at 0, initial scheduling)
        self._process_batch()
        while True:
            if all(j.status == DONE for j in self.jobs):
                break
            nt = self._next_time()
            if nt >= INF:
                break
            if self.n_batches >= limit:
                # cap hit with future events pending: the same truncation
                # signal the JAX engine's run_sim raises (SimState.truncated)
                self.truncated = True
                break
            self._accrue(nt)
            self.t = nt
            self._process_batch()
            self.n_batches += 1
        return self.metrics()

    # ---------- reporting ----------
    def metrics(self) -> SimMetrics:
        waits = [
            j.start - j.subtime for j in self.jobs if j.start >= 0
        ]
        makespan = max((j.finish for j in self.jobs if j.status == DONE), default=0.0)
        by_state = self.energy_by_state
        util = 0.0
        if makespan > 0:
            if any(sum(m) > 0 for m in self.mode_time):
                # DVFS ran: ACTIVE draw followed the mode table, so recover
                # node-seconds exactly from the per-mode energy ledger (the
                # same expression as metrics_from_state; §DVFS)
                active_node_s = sum(
                    self.mode_energy[g][m] / float(self.dvfs_watts[g, m])
                    for g in range(self.n_groups)
                    for m in range(self.dvfs_watts.shape[1])
                    if float(self.dvfs_watts[g, m]) > 0
                )
            else:
                # active node-seconds recovered per group from its own draw
                active_node_s = sum(
                    g[ACTIVE] / p_active
                    for g, p_active in zip(
                        self.energy_by_group, self.p.group_active_powers()
                    )
                    if p_active
                )
            util = active_node_s / (len(self.nodes) * makespan)
        total = float(sum(by_state))
        wasted = float(
            by_state[IDLE] + by_state[SWITCHING_ON] + by_state[SWITCHING_OFF]
        )
        return SimMetrics(
            total_energy_j=total,
            wasted_energy_j=wasted,
            energy_by_state_j=tuple(by_state),
            mean_wait_s=float(np.mean(waits)) if waits else 0.0,
            max_wait_s=float(np.max(waits)) if waits else 0.0,
            utilization=float(util),
            makespan_s=int(makespan),
            n_jobs=len(self.jobs),
            n_terminated=sum(1 for j in self.jobs if j.terminated and j.status == DONE),
            energy_by_group_j=tuple(tuple(g) for g in self.energy_by_group),
            group_names=self.p.group_names(),
            mode_residency_s=tuple(tuple(m) for m in self.mode_time),
            energy_by_mode_j=tuple(tuple(m) for m in self.mode_energy),
            truncated=self.truncated,
        )

    def schedule_table(self) -> np.ndarray:
        """(n_jobs, 3) array of [start, finish, terminated] in job order."""
        return np.array(
            [
                [j.start, (j.finish if j.status == DONE else -1.0), float(j.terminated)]
                for j in self.jobs
            ]
        )


# gantt needs node.job even when ACTIVE; patch attribute access
def _job_for_gantt(self: _Node) -> int:
    return self.job if self.state == ACTIVE else -1


_Node.job_for_gantt = property(_job_for_gantt)


def run_pydes(
    platform: PlatformSpec,
    workload: Workload,
    config: EngineConfig,
    **kw,
) -> Tuple[SimMetrics, PyDES]:
    des = PyDES(platform, workload, config, **kw)
    m = des.run()
    # flush open gantt intervals
    if config.record_gantt:
        for nd in des.nodes:
            t0, st, job = des._gantt_open[nd.nid]
            if des.t > t0:
                des.gantt.append((t0, des.t, nd.nid, st, job))
    return m, des
