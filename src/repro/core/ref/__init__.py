from repro.core.ref.pydes import PyDES, run_pydes

__all__ = ["PyDES", "run_pydes"]
