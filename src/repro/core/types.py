"""Shared types & constants for both engines (see core/SEMANTICS.md)."""
from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, NamedTuple, Optional

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only (avoids an import cycle)
    from repro.core.policy import PowerPolicy

# node power states (indexing order is part of the engine contract)
SLEEP, SWITCHING_ON, IDLE, ACTIVE, SWITCHING_OFF = 0, 1, 2, 3, 4
N_STATES = 5
STATE_NAMES = ("sleep", "switching_on", "idle", "active", "switching_off")

# job statuses
WAITING, ALLOCATED, RUNNING, DONE = 0, 1, 2, 3

INF_TIME = np.int32(2**30)  # sentinel "never" (headroom for + t_on arithmetic)


def did_you_mean(unknown, known) -> str:
    """``"; did you mean 'x'?"`` error suffix (the config-key validation
    style shared by scheduler labels, DVFS mode names, and spec keys)."""
    import difflib

    close = difflib.get_close_matches(
        str(unknown), [str(k) for k in known], n=1
    )
    return f"; did you mean {close[0]!r}?" if close else ""


class BasePolicy(enum.IntEnum):
    FCFS = 0
    EASY = 1


class PSMVariant(enum.IntEnum):
    """DEPRECATED: the legacy power-management enum.

    Survives only as a constructor shim — ``EngineConfig(psm=...)`` maps onto
    the equivalent composable policy stack (``core/policy.py``). New code
    passes ``EngineConfig(policy=...)`` (or uses ``policy.from_label``).
    """

    NONE = 0  # always-on: nodes never sleep (classic scheduler baseline)
    PSUS = 1
    PSAS = 2  # PSAS (Auto On)
    PSAS_IPM = 3
    RL = 4  # agent-controlled power commands


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration (compiled into the jitted JAX engine).

    Power management is a composable :class:`repro.core.policy.PowerPolicy`
    (``policy=``); the legacy ``psm=`` enum still works as a deprecation shim
    and is kept mirrored from ``policy`` so old readers see a consistent
    value (None for policies with no legacy twin). When both are given,
    ``policy`` wins — ``psm`` is only consulted when ``policy`` is None.
    """

    base: BasePolicy = BasePolicy.EASY
    psm: Optional[PSMVariant] = None  # DEPRECATED constructor shim
    policy: Optional["PowerPolicy"] = None  # default: TimeoutSleep() (PSUS)
    timeout: Optional[int] = None  # idle seconds before switch-off; None = never
    terminate_overrun: bool = False
    window: int = 32  # scheduler scan window W (bounded backfill depth)
    # node selection order for allocation (core/SEMANTICS.md §Heterogeneity):
    #   "id"         — (ready, nid): the homogeneous tie-breaking, O(N) path
    #   "cheap"      — (ready, order_key, nid): active watts per unit work
    #   "idle-watts" — (ready, idle_watts, nid): cheapest-to-leave-idle first
    #   "pack"       — (ready, idle_in_group, nid): queue-aware packing —
    #                  prefer groups with the fewest idle nodes so sparsely
    #                  used groups drain and become whole-group sleepable;
    #                  the key is recomputed once per scheduler pass
    node_order: str = "id"
    # allocation scope (core/SEMANTICS.md §Partition-aware allocation):
    #   "any"       — a job may span node groups (the classic rule 4; its
    #                 realized runtime then binds to the slowest chosen node)
    #   "partition" — cross-group allocations are FORBIDDEN: the job takes
    #                 the earliest-completing single group that can hold all
    #                 res_j nodes (scanning the same (ready, [key,] nid)
    #                 order), and *fails to start* when no group fits —
    #                 rather than binding its realized runtime to the
    #                 slowest node of a mixed allocation. Orthogonal to
    #                 node_order (any ordering composes).
    allocation: str = "any"
    record_gantt: bool = False
    gantt_capacity: int = 0  # 0 -> auto
    max_batches: Optional[int] = None  # safety cap; None -> auto
    rl_decision_interval: Optional[int] = None  # RL: also wake every Δ seconds
    # hot-loop structure (core/SEMANTICS.md §Hot loop). ``fused_events``
    # selects the fused per-iteration event pass (one read of the node
    # arrays for next-event time + power draw, carried across the while
    # loop, with quiet-event batching and the early-exit scheduler scan);
    # False restores the legacy loop — bit-exact either way, kept as a
    # benchmarkable baseline. ``fused_kernel`` routes the fused pass
    # through the Pallas ``event_fuse`` kernel (None = auto: TPU backend
    # only; the XLA spelling is the right choice on CPU hosts).
    fused_events: bool = True
    fused_kernel: Optional[bool] = None
    # group-indexed tables (core/SEMANTICS.md §Group-indexed tables):
    # lower the platform to per-group arrays (core/tables.py) and carry a
    # [G, 5] occupancy histogram in SimState so energy accrual and the
    # fused event pass do O(G) work instead of O(N), and the scheduler
    # pass hoists its node order out of the per-attempt loop. Schedule
    # bit-exact vs the dense path; energy agrees to f32 rounding (count x
    # power contraction vs per-node scatter-add). False keeps the dense
    # per-node path — the bit-exact baseline.
    grouped_tables: bool = False
    # merge same-timestamp arrival bursts (§Hot loop): when one timestamp
    # carries more newly-runnable jobs than the window W, repeat the
    # scheduler pass at the same t while it makes progress (and arrived
    # WAITING jobs remain) so the whole burst is scheduled in one batch.
    # Fused and legacy loops are bit-exact per label with the flag on, and
    # the oracle mirrors the same repeat rule. Vs merge_bursts=False the
    # *schedule itself* can differ (improve): without the merge, next_time
    # is strictly future, so the burst's tail past W waits for the next
    # unrelated event before it is even scanned.
    merge_bursts: bool = False
    # rule 10 (core/SEMANTICS.md §Forecast): EWMA arrival-pressure predictor
    # horizon in seconds. Like ``timeout``/``rl_decision_interval`` these
    # lower to *traced* EngineConst operands, so a forecast-horizon sweep
    # rides the one-compile grid; whether the rule runs at all is the
    # policy stack's ``forecast`` flag (``"<PSM>+Forecast"`` labels).
    # None lowers to 0 — an enabled Forecast with a zero horizon predicts
    # zero pressure and is bit-exact with its reactive base.
    forecast_horizon: Optional[int] = None
    forecast_alpha: float = 0.25  # EWMA smoothing weight in [0, 1]
    # device sharding of the sweep scenario axis (core/SEMANTICS.md
    # §Device-sharded sweeps): the default device count `engine.sweep`
    # lowers its stacked scenario batch onto (a 1-D mesh via shard_map).
    # None = unsharded single-device dispatch (the legacy jit(vmap) path);
    # an int D shards across the first D local devices; "all" takes
    # jax.device_count(). Per-scenario results are bit-exact regardless —
    # sharding only changes placement, never semantics.
    devices: Optional[object] = None

    NODE_ORDERS = ("id", "cheap", "idle-watts", "pack")
    ALLOCATIONS = ("any", "partition")

    def __post_init__(self):
        if self.node_order not in self.NODE_ORDERS:
            raise ValueError(
                f"node_order must be one of {self.NODE_ORDERS}, "
                f"got {self.node_order!r}"
            )
        if self.allocation not in self.ALLOCATIONS:
            raise ValueError(
                f"allocation must be one of {self.ALLOCATIONS}, "
                f"got {self.allocation!r}"
            )
        if self.devices is not None and self.devices != "all":
            if not isinstance(self.devices, int) or self.devices < 1:
                raise ValueError(
                    'devices must be None, a positive int, or "all", '
                    f"got {self.devices!r}"
                )
        if not 0.0 <= self.forecast_alpha <= 1.0:
            raise ValueError(
                f"forecast_alpha must be in [0, 1], got {self.forecast_alpha!r}"
            )
        if self.forecast_horizon is not None and self.forecast_horizon < 0:
            raise ValueError(
                f"forecast_horizon must be >= 0, got {self.forecast_horizon!r}"
            )
        from repro.core.policy import policy_from_psm, psm_of

        if self.policy is None:
            psm = PSMVariant.PSUS if self.psm is None else self.psm
            object.__setattr__(self, "policy", policy_from_psm(psm))
        # policy takes precedence when both are given: psm is only a
        # constructor shim, and it is auto-mirrored below — so
        # dataclasses.replace(cfg, policy=...) must not see the source
        # config's mirrored psm as a conflicting user input
        object.__setattr__(self, "psm", psm_of(self.policy))

    @property
    def timeout_or_inf(self) -> int:
        return int(INF_TIME) if self.timeout is None else int(self.timeout)

    @property
    def forecast_horizon_or_zero(self) -> int:
        return 0 if self.forecast_horizon is None else int(self.forecast_horizon)

    def label(self) -> str:
        base = "FCFS" if self.base == BasePolicy.FCFS else "EASY"
        return f"{base} {self.policy.psm_label()}"


class SimMetrics(NamedTuple):
    """Aggregate metrics (identical field meaning across both engines)."""

    total_energy_j: float
    wasted_energy_j: float
    # spars-lint: ignore[SL006] legacy per-state view, summarized by the
    # total/wasted columns; row() stays golden-file stable without it
    energy_by_state_j: tuple  # len 5, ordered by state id
    mean_wait_s: float
    max_wait_s: float
    utilization: float
    makespan_s: int
    n_jobs: int
    n_terminated: int
    # per node-group 5-tuples (group order matches PlatformSpec.groups());
    # a homogeneous platform has exactly one group == energy_by_state_j
    energy_by_group_j: tuple = ()
    group_names: tuple = ()
    # runtime DVFS ledgers (core/SEMANTICS.md §DVFS): per group x mode
    # residency seconds and ACTIVE-state energy attributed to the mode the
    # group was in while it accrued. All-zero when no DVFS policy ran.
    mode_residency_s: tuple = ()
    energy_by_mode_j: tuple = ()
    # True when the run hit its batch/log cap before completing: every
    # other field then describes a PARTIAL simulation, not a finished one
    truncated: bool = False

    def _group_labels(self, n: int) -> list:
        names = list(self.group_names) + [
            f"group{i}" for i in range(len(self.group_names), n)
        ]
        # duplicate group names would collide as dict keys and silently
        # drop groups; qualify repeats with their group index
        return [
            nm if names.count(nm) == 1 else f"{nm}{i}"
            for i, nm in enumerate(names)
        ]

    def row(self) -> dict:
        out = {
            "total_energy_kwh": self.total_energy_j / 3.6e6,
            "wasted_energy_kwh": self.wasted_energy_j / 3.6e6,
            "mean_wait_s": self.mean_wait_s,
            "max_wait_s": self.max_wait_s,
            "utilization": self.utilization,
            "makespan_s": self.makespan_s,
            "n_jobs": self.n_jobs,
            "n_terminated": self.n_terminated,
        }
        # only surfaced when it bites: a finished run keeps its legacy
        # column set (deterministic CSV/JSON goldens), a capped run is loud
        if self.truncated:
            out["truncated"] = True
        if len(self.energy_by_group_j) > 1:
            names = self._group_labels(len(self.energy_by_group_j))
            for name, e in zip(names, self.energy_by_group_j):
                out[f"energy_kwh.{name}"] = float(sum(e)) / 3.6e6
        # DVFS columns only when a DVFS policy actually ran (residency
        # accrues only under dvfs_enabled) and there is a real mode choice
        modes = self.mode_residency_s
        if modes and any(sum(m) > 0 for m in modes) and max(
            len(m) for m in modes
        ) > 1:
            names = self._group_labels(len(modes))
            for name, res, e in zip(names, modes, self.energy_by_mode_j):
                for k, (r_s, e_j) in enumerate(zip(res, e)):
                    out[f"mode_s.{name}.m{k}"] = float(r_s)
                    out[f"mode_kwh.{name}.m{k}"] = float(e_j) / 3.6e6
        return out
